//! Per-expert, per-bit reconstruction-error table ε_{i,j} (paper Eq. 6).
//!
//! For each (layer l, expert i, bit j): run the calibration tokens
//! through the **MoE block output** twice — all experts full-precision vs
//! only expert i quantized to j bits — and take the Frobenius norm of the
//! difference, normalized per token. This is PMQ's loss-sensitivity
//! signal; the same probe with the expert *dropped* gives the "expert
//! drop F-norm" of Fig. 4.

use crate::config::PmqConfig;
use crate::moe::gating::route;
use crate::moe::model::MoeModel;
use crate::quant::qlinear::QuantLinear;
use crate::quant::{binary::BinaryMatrix, packed::PackedMatrix, rtn};
use crate::tensor::silu;

/// ε table: `eps[layer][expert][bit_idx]` aligned with `pmq.bit_options`.
pub type EpsTable = Vec<Vec<Vec<f64>>>;

/// Calibration token activations per layer: the *MoE-layer inputs*
/// (post-norm), collected once by `pmq::importance::calibrate`.
pub struct LayerActivations {
    /// `[n_tokens][d_model]` rows.
    pub xs: Vec<Vec<f32>>,
}

/// Quantize one expert matrix to `bits` and return the dequantized f32
/// reconstruction (probe path — storage format irrelevant here).
fn fake_quant_expert_mat(w: &crate::tensor::Tensor2, bits: u8, group: usize) -> crate::tensor::Tensor2 {
    match bits {
        1 => BinaryMatrix::binarize(w).dequantize(),
        b => {
            let (c, s, z) = rtn::quantize_rtn(w, b, group);
            PackedMatrix::from_codes(&c, s, z, w.rows, w.cols, b, group).dequantize()
        }
    }
}

/// Compute the full ε table from per-layer calibration activations.
///
/// The block output for token x is `Σ_{j∈topk} w_j F_j(x) (+ shared)`;
/// quantizing expert i only changes the `w_i F_i(x)` term of tokens that
/// route to i, so ε_{i,j} reduces to `‖w_i (F_i(x) − F̂_i(x))‖` summed
/// over routed tokens — which is what we compute (exactly Eq. 6, cheaper).
pub fn eps_table(model: &MoeModel, acts: &[LayerActivations], pmq: &PmqConfig) -> EpsTable {
    let cfg = &model.cfg;
    let mut table =
        vec![vec![vec![0.0f64; pmq.bit_options.len()]; cfg.n_experts]; cfg.n_layers];
    for (l, block) in model.blocks.iter().enumerate() {
        let xs = &acts[l].xs;
        // routing of each calibration token at this layer
        let routes: Vec<_> = xs.iter().map(|x| route(x, &block.gate, cfg.top_k)).collect();
        for (e, expert) in block.experts.iter().enumerate() {
            // tokens that use expert e, with their routing weights
            let users: Vec<(usize, f32)> = routes
                .iter()
                .enumerate()
                .filter_map(|(t, r)| {
                    r.experts
                        .iter()
                        .position(|&ei| ei == e)
                        .map(|rank| (t, r.weights[rank]))
                })
                .collect();
            if users.is_empty() {
                // never-activated expert: quantization is free
                continue;
            }
            // full-precision outputs once
            let fp_outs: Vec<Vec<f32>> = users
                .iter()
                .map(|&(t, _)| {
                    let mut out = vec![0.0f32; cfg.d_model];
                    expert.ffn_row_acc(&xs[t], 1.0, &mut out);
                    out
                })
                .collect();
            for (bi, &bits) in pmq.bit_options.iter().enumerate() {
                let qg = fake_quant_expert_mat(&expert.wg, bits, pmq.group);
                let qu = fake_quant_expert_mat(&expert.wu, bits, pmq.group);
                let qd = fake_quant_expert_mat(&expert.wd, bits, pmq.group);
                let mut err = 0.0f64;
                for (ui, &(t, w)) in users.iter().enumerate() {
                    let x = &xs[t];
                    let f = cfg.d_ff;
                    let mut g = vec![0.0f32; f];
                    let mut u = vec![0.0f32; f];
                    for (k, &xk) in x.iter().enumerate() {
                        if xk != 0.0 {
                            crate::tensor::axpy(xk, qg.row(k), &mut g);
                            crate::tensor::axpy(xk, qu.row(k), &mut u);
                        }
                    }
                    let mut out = vec![0.0f32; cfg.d_model];
                    for j in 0..f {
                        let hj = silu(g[j]) * u[j];
                        if hj != 0.0 {
                            crate::tensor::axpy(hj, qd.row(j), &mut out);
                        }
                    }
                    let fp = &fp_outs[ui];
                    err += out
                        .iter()
                        .zip(fp)
                        .map(|(a, b)| {
                            let d = (w * (a - b)) as f64;
                            d * d
                        })
                        .sum::<f64>();
                }
                table[l][e][bi] = (err / xs.len() as f64).sqrt();
            }
        }
    }
    table
}

/// Fig. 4's "expert drop F-norm": block-output error when expert i is
/// removed entirely (its routing weight redistributed).
pub fn drop_fnorm(model: &MoeModel, acts: &[LayerActivations]) -> Vec<Vec<f64>> {
    let cfg = &model.cfg;
    let mut table = vec![vec![0.0f64; cfg.n_experts]; cfg.n_layers];
    for (l, block) in model.blocks.iter().enumerate() {
        let xs = &acts[l].xs;
        for x in xs {
            let r = route(x, &block.gate, cfg.top_k);
            for (rank, &e) in r.experts.iter().enumerate() {
                let mut out = vec![0.0f32; cfg.d_model];
                block.experts[e].ffn_row_acc(x, r.weights[rank], &mut out);
                let n: f64 = out.iter().map(|v| (*v as f64) * (*v as f64)).sum();
                table[l][e] += n;
            }
        }
        for e in 0..cfg.n_experts {
            table[l][e] = (table[l][e] / xs.len() as f64).sqrt();
        }
    }
    table
}

// QuantLinear referenced for doc cohesion.
#[allow(unused_imports)]
use QuantLinear as _;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::util::rng::Rng;

    fn setup() -> (MoeModel, Vec<LayerActivations>, PmqConfig) {
        let cfg = ModelConfig {
            name: "eps-test".into(),
            family: "mixtral".into(),
            vocab_size: 32,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 32,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let model = MoeModel::new(&cfg, 11);
        let mut rng = Rng::new(12);
        let acts = (0..2)
            .map(|_| LayerActivations {
                xs: (0..32).map(|_| rng.normal_vec(32, 1.0)).collect(),
            })
            .collect();
        (model, acts, PmqConfig::default())
    }

    #[test]
    fn eps_decreases_with_bits() {
        let (model, acts, pmq) = setup();
        let table = eps_table(&model, &acts, &pmq);
        // ε flows through the SwiGLU nonlinearity, so strict per-expert
        // monotonicity between 1-bit (sign/α) and 2-bit is not guaranteed;
        // 3-bit must beat both, and the mean must be monotone.
        let mut checked = 0;
        let mut mean = [0.0f64; 3];
        for l in 0..2 {
            for e in 0..4 {
                let row = &table[l][e];
                if row[0] == 0.0 {
                    continue; // never activated
                }
                assert!(row[0] > row[2] && row[1] > row[2], "3-bit not best: {row:?}");
                for (m, &v) in mean.iter_mut().zip(row.iter()) {
                    *m += v;
                }
                checked += 1;
            }
        }
        assert!(checked >= 4);
        assert!(mean[0] >= mean[1] && mean[1] >= mean[2], "mean ε not monotone: {mean:?}");
    }

    #[test]
    fn drop_fnorm_positive_for_used_experts() {
        let (model, acts, _) = setup();
        let t = drop_fnorm(&model, &acts);
        let used: usize = t.iter().flatten().filter(|&&v| v > 0.0).count();
        assert!(used >= 4);
    }
}
