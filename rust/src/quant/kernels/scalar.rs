//! Portable fused kernels — the dispatch fallback and the reference the
//! SIMD path is property-tested against (`tests/kernel_equivalence.rs`).
//!
//! Same group-affine factorization as the AVX2 path: within one
//! quantization group accumulate `qacc[o] = Σ_r x_r·q[r,o]` and
//! `xsum = Σ_r x_r`, then apply `y[o] += s[o]·(qacc[o] − z[o]·xsum)`
//! once per group — the f32 weight matrix is never materialized. The
//! per-byte 0/1 LUT turns bit tests into pure FMAs (no per-element
//! shifts in the inner loop), which the compiler auto-vectorizes on any
//! target; `BITS` is a const generic so each bit-width gets its own
//! monomorphized loop nest.

use super::repack::Repacked;
use super::{Dims, BIT_LUT, PLANE_WEIGHTS};

/// `y += x @ dequant` for one token.
// analyze: hot-path
pub(super) fn matvec<const BITS: usize>(
    rp: &Repacked,
    d: Dims,
    x: &[f32],
    y: &mut [f32],
    qacc: &mut [f32],
) {
    let dp = rp.dp;
    let bpg = d.group / 8;
    for gi in 0..d.d_in / d.group {
        qacc[..dp].fill(0.0);
        let mut xsum = 0.0f32;
        for bq in 0..bpg {
            let br = gi * bpg + bq;
            let x8 = &x[br * 8..br * 8 + 8];
            if x8.iter().all(|&v| v == 0.0) {
                continue;
            }
            xsum += x8.iter().sum::<f32>();
            for p in 0..BITS {
                let pw = PLANE_WEIGHTS[p];
                let xw = [
                    x8[0] * pw,
                    x8[1] * pw,
                    x8[2] * pw,
                    x8[3] * pw,
                    x8[4] * pw,
                    x8[5] * pw,
                    x8[6] * pw,
                    x8[7] * pw,
                ];
                let row = &rp.data[(br * BITS + p) * dp..][..dp];
                for o in 0..d.d_out {
                    let l = &BIT_LUT[row[o] as usize];
                    qacc[o] += l[0] * xw[0]
                        + l[1] * xw[1]
                        + l[2] * xw[2]
                        + l[3] * xw[3]
                        + l[4] * xw[4]
                        + l[5] * xw[5]
                        + l[6] * xw[6]
                        + l[7] * xw[7];
                }
            }
        }
        let srow = &rp.scales[gi * dp..][..dp];
        let zrow = &rp.zeros[gi * dp..][..dp];
        for o in 0..d.d_out {
            y[o] += srow[o] * (qacc[o] - zrow[o] * xsum);
        }
    }
}

/// Batched `y += x @ dequant` over `t` tokens: decode each group tile
/// into scratch once, reuse it for every token row.
// analyze: hot-path
pub(super) fn matmul<const BITS: usize>(
    rp: &Repacked,
    d: Dims,
    x: &[f32],
    t: usize,
    y: &mut [f32],
    tile: &mut [f32],
) {
    let dp = rp.dp;
    let bpg = d.group / 8;
    for gi in 0..d.d_in / d.group {
        let srow = &rp.scales[gi * dp..][..dp];
        let zrow = &rp.zeros[gi * dp..][..dp];
        for bq in 0..bpg {
            let br = gi * bpg + bq;
            for o in 0..d.d_out {
                let mut q = [0.0f32; 8];
                for p in 0..BITS {
                    let pw = PLANE_WEIGHTS[p];
                    let l = &BIT_LUT[rp.data[(br * BITS + p) * dp + o] as usize];
                    for j in 0..8 {
                        q[j] += pw * l[j];
                    }
                }
                let (sv, zv) = (srow[o], zrow[o]);
                for j in 0..8 {
                    tile[(bq * 8 + j) * dp + o] = (q[j] - zv) * sv;
                }
            }
        }
        token_acc(rp, tile, d.group, x, t, &d, gi * d.group, y);
    }
}

/// Binary Eq. 9: accumulate `qacc[o] = Σ_{bit=1} x_r`, one α multiply
/// per output channel in the epilogue.
// analyze: hot-path
pub(super) fn binary_matvec(rp: &Repacked, d_out: usize, x: &[f32], y: &mut [f32], qacc: &mut [f32]) {
    let dp = rp.dp;
    qacc[..dp].fill(0.0);
    let mut xsum = 0.0f32;
    for (br, x8) in x.chunks_exact(8).enumerate() {
        if x8.iter().all(|&v| v == 0.0) {
            continue;
        }
        xsum += x8.iter().sum::<f32>();
        let row = &rp.data[br * dp..][..dp];
        for o in 0..d_out {
            let l = &BIT_LUT[row[o] as usize];
            qacc[o] += l[0] * x8[0]
                + l[1] * x8[1]
                + l[2] * x8[2]
                + l[3] * x8[3]
                + l[4] * x8[4]
                + l[5] * x8[5]
                + l[6] * x8[6]
                + l[7] * x8[7];
        }
    }
    for o in 0..d_out {
        y[o] += rp.scales[o] * (2.0 * qacc[o] - xsum);
    }
}

/// Batched binary: decode the `α·(2b−1)` tile for a block of input rows
/// (`d.group` = the row-block size here) and reuse it for every token.
// analyze: hot-path
pub(super) fn binary_matmul(
    rp: &Repacked,
    d: Dims,
    x: &[f32],
    t: usize,
    y: &mut [f32],
    tile: &mut [f32],
) {
    let dp = rp.dp;
    let mut row0 = 0;
    while row0 < d.d_in {
        let rows = d.group.min(d.d_in - row0);
        for bq in 0..rows / 8 {
            let br = row0 / 8 + bq;
            for o in 0..d.d_out {
                let l = &BIT_LUT[rp.data[br * dp + o] as usize];
                let a = rp.scales[o];
                for j in 0..8 {
                    tile[(bq * 8 + j) * dp + o] = a * (2.0 * l[j] - 1.0);
                }
            }
        }
        token_acc(rp, tile, rows, x, t, &d, row0, y);
        row0 += rows;
    }
}

/// `y[ti] += x[ti, row0..row0+rows] @ tile` for every token row.
// analyze: hot-path
#[allow(clippy::too_many_arguments)]
fn token_acc(
    rp: &Repacked,
    tile: &[f32],
    rows: usize,
    x: &[f32],
    t: usize,
    d: &Dims,
    row0: usize,
    y: &mut [f32],
) {
    let dp = rp.dp;
    for ti in 0..t {
        let xr = &x[ti * d.d_in + row0..][..rows];
        let yrow = &mut y[ti * d.d_out..][..d.d_out];
        for (rq, &xv) in xr.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            crate::tensor::axpy(xv, &tile[rq * dp..][..d.d_out], yrow);
        }
    }
}
