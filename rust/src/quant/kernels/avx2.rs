//! AVX2+FMA specializations of the fused kernels.
//!
//! Lane layout: one `__m256` covers 8 consecutive output columns (the
//! repack pads `d_out` to `dp`, a multiple of 8, so every load/store on
//! repacked data and scratch is full-width; only stores into the
//! caller's unpadded `y` take a scalar tail). A plane byte holds bit
//! `j` for input row `8·byte_row + j` of one column, so 8 column bytes
//! are zero-extended to i32 lanes and tested against `set1(1 << j)`;
//! `cmpeq` turns the test into an all-ones mask that either passes or
//! zeroes the broadcast `x[row]·2^plane` addend — branch-free and with
//! no variable-distance shifts (AVX2 immediate shifts take constants,
//! so the mask-compare form is the vector analog of the scalar LUT).
//!
//! Everything here is reached through non-generic wrappers carrying
//! `#[target_feature(enable = "avx2,fma")]`; the `#[inline(always)]`
//! const-generic cores inline into them and inherit the features. The
//! dispatcher (`kernels::active_isa`) performs the runtime CPUID check
//! before any call lands here.

use std::arch::x86_64::*;

use super::repack::Repacked;
use super::{Dims, PLANE_WEIGHTS};

/// Per-lane test masks: `masks[j]` selects bit `j` in every lane.
///
/// # Safety
/// Requires AVX2 at runtime; every caller sits inside (or inlines
/// into) a `target_feature(avx2,fma)` wrapper behind the CPUID check.
#[inline(always)]
unsafe fn bit_masks() -> [__m256i; 8] {
    // SAFETY: `_mm256_set1_epi32` only needs AVX2, guaranteed by the
    // caller per this fn's contract.
    unsafe {
        [
            _mm256_set1_epi32(1),
            _mm256_set1_epi32(2),
            _mm256_set1_epi32(4),
            _mm256_set1_epi32(8),
            _mm256_set1_epi32(16),
            _mm256_set1_epi32(32),
            _mm256_set1_epi32(64),
            _mm256_set1_epi32(128),
        ]
    }
}

/// 8 plane bytes (8 output columns) → 8 zero-extended i32 lanes.
///
/// # Safety
/// Requires AVX2 at runtime and `p` valid for an 8-byte read; callers
/// point `p` into repacked plane rows, which are padded to `dp` (a
/// multiple of 8) columns.
#[inline(always)]
unsafe fn load8(p: *const u8) -> __m256i {
    // SAFETY: caller guarantees 8 readable bytes at `p` (padded plane
    // row) and AVX2 availability; `_mm_loadl_epi64` is unaligned.
    unsafe { _mm256_cvtepu8_epi32(_mm_loadl_epi64(p as *const __m128i)) }
}

/// # Safety
/// Requires AVX2+FMA at runtime (guaranteed by the dispatcher). Slice
/// lengths are validated by the `kernels` entry points.
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn packed_matvec(
    bits: usize,
    rp: &Repacked,
    d: Dims,
    x: &[f32],
    y: &mut [f32],
    qacc: &mut [f32],
) {
    // SAFETY: the cores need AVX2+FMA — this fn's target_feature
    // contract — plus the entry-point length checks, forwarded intact.
    unsafe {
        match bits {
            1 => matvec_core::<1>(rp, d, x, y, qacc),
            2 => matvec_core::<2>(rp, d, x, y, qacc),
            3 => matvec_core::<3>(rp, d, x, y, qacc),
            4 => matvec_core::<4>(rp, d, x, y, qacc),
            b => panic!("fused kernels cover bits 1..=4, got {b}"),
        }
    }
}

/// # Safety
/// Requires AVX2+FMA at runtime (guaranteed by the dispatcher). Slice
/// lengths are validated by the `kernels` entry points.
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn packed_matmul(
    bits: usize,
    rp: &Repacked,
    d: Dims,
    x: &[f32],
    t: usize,
    y: &mut [f32],
    tile: &mut [f32],
) {
    // SAFETY: the cores need AVX2+FMA — this fn's target_feature
    // contract — plus the entry-point length checks, forwarded intact.
    unsafe {
        match bits {
            1 => matmul_core::<1>(rp, d, x, t, y, tile),
            2 => matmul_core::<2>(rp, d, x, t, y, tile),
            3 => matmul_core::<3>(rp, d, x, t, y, tile),
            4 => matmul_core::<4>(rp, d, x, t, y, tile),
            b => panic!("fused kernels cover bits 1..=4, got {b}"),
        }
    }
}

/// # Safety
/// Requires AVX2+FMA at runtime and the `kernels` entry-point length
/// checks: `x` is `d_in`, `y` is `d_out`, `qacc` covers `dp`, and the
/// repacked planes/scales/zeros are padded to `dp` columns.
#[inline(always)]
unsafe fn matvec_core<const BITS: usize>(
    rp: &Repacked,
    d: Dims,
    x: &[f32],
    y: &mut [f32],
    qacc: &mut [f32],
) {
    // SAFETY: all pointer arithmetic stays inside the repack layout —
    // plane rows and scale/zero rows are `dp` wide (multiple of 8, so
    // every 8-wide load is in bounds) and stores into unpadded `y` take
    // the scalar tail; AVX2+FMA comes from the caller's contract.
    unsafe {
        let dp = rp.dp;
        let bpg = d.group / 8;
        let masks = bit_masks();
        for gi in 0..d.d_in / d.group {
            qacc[..dp].fill(0.0);
            let mut xsum = 0.0f32;
            for bq in 0..bpg {
                let br = gi * bpg + bq;
                let x8 = &x[br * 8..br * 8 + 8];
                if x8.iter().all(|&v| v == 0.0) {
                    continue;
                }
                xsum += x8.iter().sum::<f32>();
                for p in 0..BITS {
                    let pw = PLANE_WEIGHTS[p];
                    let mut xw = [_mm256_setzero_ps(); 8];
                    for j in 0..8 {
                        xw[j] = _mm256_set1_ps(x8[j] * pw);
                    }
                    let row = rp.data.as_ptr().add((br * BITS + p) * dp);
                    let mut oc = 0;
                    while oc < dp {
                        let v = load8(row.add(oc));
                        let mut acc = _mm256_loadu_ps(qacc.as_ptr().add(oc));
                        for j in 0..8 {
                            let hit =
                                _mm256_cmpeq_epi32(_mm256_and_si256(v, masks[j]), masks[j]);
                            acc = _mm256_add_ps(
                                acc,
                                _mm256_and_ps(_mm256_castsi256_ps(hit), xw[j]),
                            );
                        }
                        _mm256_storeu_ps(qacc.as_mut_ptr().add(oc), acc);
                        oc += 8;
                    }
                }
            }
            // epilogue: y += s ⊙ (qacc − z·xsum), vector main + scalar tail
            // (y is unpadded; scales/zeros are padded so 8-wide loads are safe)
            let srow = &rp.scales[gi * dp..][..dp];
            let zrow = &rp.zeros[gi * dp..][..dp];
            let xs = _mm256_set1_ps(xsum);
            let mut o = 0;
            while o + 8 <= d.d_out {
                let q = _mm256_loadu_ps(qacc.as_ptr().add(o));
                let z = _mm256_loadu_ps(zrow.as_ptr().add(o));
                let sv = _mm256_loadu_ps(srow.as_ptr().add(o));
                let acc = _mm256_fnmadd_ps(z, xs, q); // q − z·xsum
                let yv = _mm256_loadu_ps(y.as_ptr().add(o));
                _mm256_storeu_ps(y.as_mut_ptr().add(o), _mm256_fmadd_ps(sv, acc, yv));
                o += 8;
            }
            while o < d.d_out {
                y[o] += srow[o] * (qacc[o] - zrow[o] * xsum);
                o += 1;
            }
        }
    }
}

/// # Safety
/// Requires AVX2+FMA at runtime and the `kernels` entry-point length
/// checks: `x` is `t·d_in`, `y` is `t·d_out`, `tile` covers
/// `group·dp`, and the repacked planes/scales/zeros are padded to `dp`
/// columns.
#[inline(always)]
unsafe fn matmul_core<const BITS: usize>(
    rp: &Repacked,
    d: Dims,
    x: &[f32],
    t: usize,
    y: &mut [f32],
    tile: &mut [f32],
) {
    // SAFETY: tile stores index `(bq·8 + j)·dp + oc` with `bq·8 + j <
    // group` and `oc < dp`, inside the caller-sized `group·dp` scratch;
    // plane reads stay inside padded rows; AVX2+FMA per the contract.
    unsafe {
        let dp = rp.dp;
        let bpg = d.group / 8;
        let masks = bit_masks();
        let mut pw_i = [_mm256_setzero_si256(); BITS];
        for p in 0..BITS {
            pw_i[p] = _mm256_set1_epi32(1 << p);
        }
        for gi in 0..d.d_in / d.group {
            // decode this group's [group, dp] tile once (integer plane
            // accumulate → cvt → (q − z)·s), padded columns decode to 0
            let srow = &rp.scales[gi * dp..][..dp];
            let zrow = &rp.zeros[gi * dp..][..dp];
            for bq in 0..bpg {
                let br = gi * bpg + bq;
                let mut oc = 0;
                while oc < dp {
                    let mut planes = [_mm256_setzero_si256(); BITS];
                    for p in 0..BITS {
                        planes[p] = load8(rp.data.as_ptr().add((br * BITS + p) * dp + oc));
                    }
                    let sv = _mm256_loadu_ps(srow.as_ptr().add(oc));
                    let zv = _mm256_loadu_ps(zrow.as_ptr().add(oc));
                    for j in 0..8 {
                        let mut qi = _mm256_setzero_si256();
                        for p in 0..BITS {
                            let hit = _mm256_cmpeq_epi32(
                                _mm256_and_si256(planes[p], masks[j]),
                                masks[j],
                            );
                            qi = _mm256_add_epi32(qi, _mm256_and_si256(hit, pw_i[p]));
                        }
                        let w =
                            _mm256_mul_ps(_mm256_sub_ps(_mm256_cvtepi32_ps(qi), zv), sv);
                        _mm256_storeu_ps(tile.as_mut_ptr().add((bq * 8 + j) * dp + oc), w);
                    }
                    oc += 8;
                }
            }
            token_acc(rp, tile, d.group, x, t, &d, gi * d.group, y);
        }
    }
}

/// # Safety
/// Requires AVX2+FMA at runtime (guaranteed by the dispatcher). Slice
/// lengths are validated by the `kernels` entry points.
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn binary_matvec(
    rp: &Repacked,
    d_out: usize,
    x: &[f32],
    y: &mut [f32],
    qacc: &mut [f32],
) {
    // SAFETY: plane rows and `qacc` are `dp` wide (multiple of 8), so
    // the 8-wide loop loads/stores are in bounds; `y` writes past the
    // vector main loop take the scalar tail; AVX2+FMA per this fn's
    // target_feature contract.
    unsafe {
        let dp = rp.dp;
        let masks = bit_masks();
        qacc[..dp].fill(0.0);
        let mut xsum = 0.0f32;
        for (br, x8) in x.chunks_exact(8).enumerate() {
            if x8.iter().all(|&v| v == 0.0) {
                continue;
            }
            xsum += x8.iter().sum::<f32>();
            let mut xw = [_mm256_setzero_ps(); 8];
            for j in 0..8 {
                xw[j] = _mm256_set1_ps(x8[j]);
            }
            let row = rp.data.as_ptr().add(br * dp);
            let mut oc = 0;
            while oc < dp {
                let v = load8(row.add(oc));
                let mut acc = _mm256_loadu_ps(qacc.as_ptr().add(oc));
                for j in 0..8 {
                    let hit = _mm256_cmpeq_epi32(_mm256_and_si256(v, masks[j]), masks[j]);
                    acc =
                        _mm256_add_ps(acc, _mm256_and_ps(_mm256_castsi256_ps(hit), xw[j]));
                }
                _mm256_storeu_ps(qacc.as_mut_ptr().add(oc), acc);
                oc += 8;
            }
        }
        // Eq. 9 epilogue: y += α ⊙ (2·qacc − xsum)
        let xs = _mm256_set1_ps(xsum);
        let two = _mm256_set1_ps(2.0);
        let mut o = 0;
        while o + 8 <= d_out {
            let q = _mm256_loadu_ps(qacc.as_ptr().add(o));
            let a = _mm256_loadu_ps(rp.scales.as_ptr().add(o));
            let acc = _mm256_fmsub_ps(two, q, xs); // 2q − xsum
            let yv = _mm256_loadu_ps(y.as_ptr().add(o));
            _mm256_storeu_ps(y.as_mut_ptr().add(o), _mm256_fmadd_ps(a, acc, yv));
            o += 8;
        }
        while o < d_out {
            y[o] += rp.scales[o] * (2.0 * qacc[o] - xsum);
            o += 1;
        }
    }
}

/// # Safety
/// Requires AVX2+FMA at runtime (guaranteed by the dispatcher). Slice
/// lengths are validated by the `kernels` entry points.
#[target_feature(enable = "avx2,fma")]
pub(super) unsafe fn binary_matmul(
    rp: &Repacked,
    d: Dims,
    x: &[f32],
    t: usize,
    y: &mut [f32],
    tile: &mut [f32],
) {
    // SAFETY: tile stores stay inside the caller-sized `rows·dp`
    // scratch and plane reads inside padded `dp`-wide rows; AVX2+FMA
    // per this fn's target_feature contract.
    unsafe {
        let dp = rp.dp;
        let masks = bit_masks();
        let two = _mm256_set1_ps(2.0);
        let onef = _mm256_set1_ps(1.0);
        let onei = _mm256_set1_epi32(1);
        let mut row0 = 0;
        while row0 < d.d_in {
            // decode an α·(2b−1) tile for a block of input rows (d.group =
            // the row-block size here), reuse it for every token
            let rows = d.group.min(d.d_in - row0);
            for bq in 0..rows / 8 {
                let br = row0 / 8 + bq;
                let mut oc = 0;
                while oc < dp {
                    let v = load8(rp.data.as_ptr().add(br * dp + oc));
                    let a = _mm256_loadu_ps(rp.scales.as_ptr().add(oc));
                    for j in 0..8 {
                        let hit =
                            _mm256_cmpeq_epi32(_mm256_and_si256(v, masks[j]), masks[j]);
                        let b = _mm256_cvtepi32_ps(_mm256_and_si256(hit, onei));
                        let w = _mm256_mul_ps(a, _mm256_fmsub_ps(two, b, onef));
                        _mm256_storeu_ps(tile.as_mut_ptr().add((bq * 8 + j) * dp + oc), w);
                    }
                    oc += 8;
                }
            }
            token_acc(rp, tile, rows, x, t, &d, row0, y);
            row0 += rows;
        }
    }
}

/// `y[ti] += x[ti, row0..row0+rows] @ tile` for every token row: the
/// output axis is chunked 16 floats wide (2 ymm accumulators per token)
/// so each y chunk stays in registers across the whole row block.
///
/// # Safety
/// Requires AVX2+FMA at runtime; `tile` must hold `rows·dp` decoded
/// weights, `x` `t·d_in` inputs, and `y` `t·d_out` outputs (the entry
/// points assert the latter two).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
unsafe fn token_acc(
    rp: &Repacked,
    tile: &[f32],
    rows: usize,
    x: &[f32],
    t: usize,
    d: &Dims,
    row0: usize,
    y: &mut [f32],
) {
    // SAFETY: y pointers stay under `t·d_out` (the 16/8-wide loops only
    // run while `oc + width <= d_out`) and tile pointers under
    // `rows·dp`; AVX2+FMA comes from the caller's contract.
    unsafe {
        let dp = rp.dp;
        let mut oc = 0;
        while oc + 16 <= d.d_out {
            for ti in 0..t {
                let xr = &x[ti * d.d_in + row0..][..rows];
                let yp = y.as_mut_ptr().add(ti * d.d_out + oc);
                let mut a0 = _mm256_loadu_ps(yp);
                let mut a1 = _mm256_loadu_ps(yp.add(8));
                for (rq, &xv) in xr.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let tp = tile.as_ptr().add(rq * dp + oc);
                    let xb = _mm256_set1_ps(xv);
                    a0 = _mm256_fmadd_ps(xb, _mm256_loadu_ps(tp), a0);
                    a1 = _mm256_fmadd_ps(xb, _mm256_loadu_ps(tp.add(8)), a1);
                }
                _mm256_storeu_ps(yp, a0);
                _mm256_storeu_ps(yp.add(8), a1);
            }
            oc += 16;
        }
        if oc + 8 <= d.d_out {
            for ti in 0..t {
                let xr = &x[ti * d.d_in + row0..][..rows];
                let yp = y.as_mut_ptr().add(ti * d.d_out + oc);
                let mut a0 = _mm256_loadu_ps(yp);
                for (rq, &xv) in xr.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    a0 = _mm256_fmadd_ps(
                        _mm256_set1_ps(xv),
                        _mm256_loadu_ps(tile.as_ptr().add(rq * dp + oc)),
                        a0,
                    );
                }
                _mm256_storeu_ps(yp, a0);
            }
            oc += 8;
        }
        if oc < d.d_out {
            for ti in 0..t {
                let xr = &x[ti * d.d_in + row0..][..rows];
                for (rq, &xv) in xr.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let trow = &tile[rq * dp..][..dp];
                    for o in oc..d.d_out {
                        y[ti * d.d_out + o] += xv * trow[o];
                    }
                }
            }
        }
    }
}
