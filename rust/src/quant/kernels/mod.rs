//! SIMD-specialized fused dequant×matmul kernel layer — the native-CPU
//! analog of the Pallas kernel in
//! `python/compile/kernels/dequant_matmul.py`.
//!
//! Everything quantized funnels through here: `QuantLinear` wraps these
//! entry points, so token-group dispatch, `QuantExpert::ffn_batch_acc`
//! and the serving decode engine all ride the same kernels with no
//! call-site changes.
//!
//! * `repack` — a SIMD-friendly interleaved, padded copy of the
//!   bit-planes, computed once at pack/load time and cached on the
//!   matrix (see [`Repacked`]).
//! * `scalar` — portable monomorphized kernels (const-generic
//!   `BITS ∈ {1,2,3,4}`): the fallback path and the reference the SIMD
//!   path is property-tested against.
//! * `avx2` — AVX2+FMA kernels behind one runtime feature-detect.
//!
//! Dispatch is decided per call by [`active_isa`]: a cached CPUID check
//! (`is_x86_feature_detected!`), overridable per-thread with
//! [`force_scalar`] (tests) or globally with the `MCSHARP_FORCE_SCALAR`
//! environment variable (benches, CI on non-AVX2 hosts).
//!
//! Callers provide scratch through the thread-local arena
//! ([`with_scratch`]) so the steady-state decode loop — which runs
//! inline on the engine thread below the dispatcher's
//! `PAR_MIN_VOLUME` — performs zero allocations.

#[cfg(target_arch = "x86_64")]
mod avx2;
pub mod repack;
mod scalar;

use std::cell::Cell;
use std::sync::OnceLock;

use super::binary::BinaryMatrix;
use super::packed::PackedMatrix;
pub use repack::Repacked;

/// 2^p weights for plane accumulation (bit-plane p contributes 2^p·bit).
pub(crate) const PLANE_WEIGHTS: [f32; 4] = [1.0, 2.0, 4.0, 8.0];

/// `[byte] -> [0/1; 8]` expansion: bit j of a plane byte is the code bit
/// of input row `8·byte_row + j`.
pub(crate) static BIT_LUT: [[f32; 8]; 256] = make_bit_lut();

const fn make_bit_lut() -> [[f32; 8]; 256] {
    let mut l = [[0.0f32; 8]; 256];
    let mut b = 0;
    while b < 256 {
        let mut j = 0;
        while j < 8 {
            if (b >> j) & 1 == 1 {
                l[b][j] = 1.0;
            }
            j += 1;
        }
        b += 1;
    }
    l
}

/// Logical dims of a packed operand (the padded width lives in
/// [`Repacked::dp`]). For binary matmuls `group` carries the row-block
/// size instead of a quantization group.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Dims {
    pub d_in: usize,
    pub d_out: usize,
    pub group: usize,
}

// ------------------------------------------------------------- dispatch

/// Which kernel family a call lands on.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Isa {
    /// AVX2 + FMA `std::arch` path.
    Avx2Fma,
    /// Portable scalar path (also the forced-fallback reference).
    Scalar,
}

/// The ISA the next kernel call on this thread will dispatch to.
pub fn active_isa() -> Isa {
    if FORCE_SCALAR.with(|c| c.get()) {
        return Isa::Scalar;
    }
    if simd_available() {
        Isa::Avx2Fma
    } else {
        Isa::Scalar
    }
}

/// Whether this CPU supports the SIMD path at all (cached CPUID check;
/// ignores the per-thread [`force_scalar`] override but honors the
/// `MCSHARP_FORCE_SCALAR` environment variable).
pub fn simd_available() -> bool {
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        if std::env::var_os("MCSHARP_FORCE_SCALAR").is_some() {
            return false;
        }
        detect_arch()
    })
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

#[cfg(not(target_arch = "x86_64"))]
fn detect_arch() -> bool {
    false
}

thread_local! {
    static FORCE_SCALAR: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` with SIMD dispatch disabled on this thread — tests pin the
/// scalar path, benches measure it. Thread-local (not global) so
/// parallel tests never race each other's dispatch.
pub fn force_scalar<R>(f: impl FnOnce() -> R) -> R {
    struct Reset(bool);
    impl Drop for Reset {
        fn drop(&mut self) {
            FORCE_SCALAR.with(|c| c.set(self.0));
        }
    }
    let _reset = Reset(FORCE_SCALAR.with(|c| c.replace(true)));
    f()
}

// -------------------------------------------------------------- scratch

/// Reusable f32 buffers for the kernel layer and the quantized expert
/// FFN: one arena per thread (see [`with_scratch`]), grown on demand and
/// never shrunk, so the steady-state hot path allocates nothing.
#[derive(Default)]
pub struct Scratch {
    /// Per-group `Σ x_r·q[r,o]` accumulator (matvec kernels), `dp` floats.
    qacc: Vec<f32>,
    /// Dequantized group tile (matmul kernels), `group × dp` floats.
    tile: Vec<f32>,
    /// Scaled-activation prologue buffer (AWQ `Scaled` operands).
    xbuf: Vec<f32>,
    /// Expert-level arenas (`g`/`u`/weighted-tmp in the SwiGLU FFN).
    pool: [Vec<f32>; 3],
}

impl Scratch {
    /// Borrow a pool buffer, zero-filled to `n`. Taken by value (slot
    /// left empty) so several slots can be live simultaneously; return
    /// it with [`Scratch::put_pool`] to keep the capacity for the next
    /// call.
    pub fn take_pool(&mut self, slot: usize, n: usize) -> Vec<f32> {
        let mut v = std::mem::take(&mut self.pool[slot]);
        v.clear();
        v.resize(n, 0.0);
        v
    }

    pub fn put_pool(&mut self, slot: usize, v: Vec<f32>) {
        self.pool[slot] = v;
    }
}

fn grow(buf: &mut Vec<f32>, n: usize) -> &mut [f32] {
    if buf.len() < n {
        buf.resize(n, 0.0);
    }
    &mut buf[..n]
}

thread_local! {
    static SCRATCH: Cell<Option<Box<Scratch>>> = const { Cell::new(None) };
}

/// Run `f` with this thread's scratch arena (created on first use).
/// Take/put instead of `RefCell` so a nested call degrades to a fresh
/// allocation for the inner scope rather than a borrow panic.
pub fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    let mut s = SCRATCH.with(|c| c.take()).unwrap_or_default();
    let r = f(&mut s);
    SCRATCH.with(|c| c.set(Some(s)));
    r
}

// --------------------------------------------------------- entry points

/// Fused `y += x @ dequant(pm)` for one token, ISA-dispatched.
// analyze: hot-path
pub fn packed_matvec(pm: &PackedMatrix, x: &[f32], y: &mut [f32], s: &mut Scratch) {
    assert_eq!(x.len(), pm.d_in);
    assert_eq!(y.len(), pm.d_out);
    assert_eq!(pm.group % 8, 0, "group must be a multiple of 8");
    let rp = pm.repacked();
    let dims = Dims { d_in: pm.d_in, d_out: pm.d_out, group: pm.group };
    let qacc = grow(&mut s.qacc, rp.dp);
    match active_isa() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_isa() returned Avx2Fma only after the cached
        // CPUID check; slice lengths were asserted above.
        Isa::Avx2Fma => unsafe {
            avx2::packed_matvec(pm.bits as usize, rp, dims, x, y, qacc)
        },
        _ => match pm.bits {
            1 => scalar::matvec::<1>(rp, dims, x, y, qacc),
            2 => scalar::matvec::<2>(rp, dims, x, y, qacc),
            3 => scalar::matvec::<3>(rp, dims, x, y, qacc),
            4 => scalar::matvec::<4>(rp, dims, x, y, qacc),
            b => panic!("fused kernels cover bits 1..=4, got {b}"),
        },
    }
}

/// Batched fused `y += x @ dequant(pm)` over `t` tokens (`x` row-major
/// `[t, d_in]`, `y` `[t, d_out]`): each group tile is decoded into
/// scratch once and reused by every token.
// analyze: hot-path
pub fn packed_matmul(pm: &PackedMatrix, x: &[f32], t: usize, y: &mut [f32], s: &mut Scratch) {
    assert_eq!(x.len(), t * pm.d_in);
    assert_eq!(y.len(), t * pm.d_out);
    assert_eq!(pm.group % 8, 0, "group must be a multiple of 8");
    let rp = pm.repacked();
    let dims = Dims { d_in: pm.d_in, d_out: pm.d_out, group: pm.group };
    let tile = grow(&mut s.tile, pm.group * rp.dp);
    match active_isa() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_isa() returned Avx2Fma only after the cached
        // CPUID check; slice lengths were asserted above.
        Isa::Avx2Fma => unsafe {
            avx2::packed_matmul(pm.bits as usize, rp, dims, x, t, y, tile)
        },
        _ => match pm.bits {
            1 => scalar::matmul::<1>(rp, dims, x, t, y, tile),
            2 => scalar::matmul::<2>(rp, dims, x, t, y, tile),
            3 => scalar::matmul::<3>(rp, dims, x, t, y, tile),
            4 => scalar::matmul::<4>(rp, dims, x, t, y, tile),
            b => panic!("fused kernels cover bits 1..=4, got {b}"),
        },
    }
}

/// AWQ `Scaled` prologue + fused matvec: fold the per-input-channel
/// `inv_s` into the activations inside scratch (no allocation, no
/// clone), then run the packed kernel on the `diag(s)·W` codes.
pub fn packed_matvec_scaled(
    pm: &PackedMatrix,
    inv_s: &[f32],
    x: &[f32],
    y: &mut [f32],
    s: &mut Scratch,
) {
    assert_eq!(inv_s.len(), pm.d_in);
    assert_eq!(x.len(), pm.d_in);
    let mut xbuf = std::mem::take(&mut s.xbuf);
    xbuf.clear();
    xbuf.extend(x.iter().zip(inv_s).map(|(&v, &si)| v * si));
    packed_matvec(pm, &xbuf, y, s);
    s.xbuf = xbuf;
}

/// AWQ `Scaled` prologue + batched fused matmul (see
/// [`packed_matvec_scaled`]).
pub fn packed_matmul_scaled(
    pm: &PackedMatrix,
    inv_s: &[f32],
    x: &[f32],
    t: usize,
    y: &mut [f32],
    s: &mut Scratch,
) {
    assert_eq!(inv_s.len(), pm.d_in);
    assert_eq!(x.len(), t * pm.d_in);
    let mut xbuf = std::mem::take(&mut s.xbuf);
    xbuf.clear();
    xbuf.reserve(t * pm.d_in);
    for ti in 0..t {
        let xr = &x[ti * pm.d_in..][..pm.d_in];
        xbuf.extend(xr.iter().zip(inv_s).map(|(&v, &si)| v * si));
    }
    packed_matmul(pm, &xbuf, t, y, s);
    s.xbuf = xbuf;
}

/// Fused binary matvec (Eq. 9), ISA-dispatched.
// analyze: hot-path
pub fn binary_matvec(bm: &BinaryMatrix, x: &[f32], y: &mut [f32], s: &mut Scratch) {
    assert_eq!(x.len(), bm.d_in);
    assert_eq!(y.len(), bm.d_out);
    let rp = bm.repacked();
    let qacc = grow(&mut s.qacc, rp.dp);
    match active_isa() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_isa() returned Avx2Fma only after the cached
        // CPUID check; slice lengths were asserted above.
        Isa::Avx2Fma => unsafe { avx2::binary_matvec(rp, bm.d_out, x, y, qacc) },
        _ => scalar::binary_matvec(rp, bm.d_out, x, y, qacc),
    }
}

/// Input-row block size for the batched binary tile — plays the role a
/// quantization group does for packed operands: keeps the decoded
/// `α·(2b−1)` tile L1-resident while every token reuses it.
const BINARY_TILE_ROWS: usize = 64;

/// Batched fused binary matmul over `t` tokens.
// analyze: hot-path
pub fn binary_matmul(bm: &BinaryMatrix, x: &[f32], t: usize, y: &mut [f32], s: &mut Scratch) {
    assert_eq!(x.len(), t * bm.d_in);
    assert_eq!(y.len(), t * bm.d_out);
    let rp = bm.repacked();
    let rows = BINARY_TILE_ROWS.min(bm.d_in);
    let dims = Dims { d_in: bm.d_in, d_out: bm.d_out, group: rows };
    let tile = grow(&mut s.tile, rows * rp.dp);
    match active_isa() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: active_isa() returned Avx2Fma only after the cached
        // CPUID check; slice lengths were asserted above.
        Isa::Avx2Fma => unsafe { avx2::binary_matmul(rp, dims, x, t, y, tile) },
        _ => scalar::binary_matmul(rp, dims, x, t, y, tile),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn force_scalar_is_scoped_and_nested() {
        let outer = active_isa();
        force_scalar(|| {
            assert_eq!(active_isa(), Isa::Scalar);
            force_scalar(|| assert_eq!(active_isa(), Isa::Scalar));
            assert_eq!(active_isa(), Isa::Scalar);
        });
        assert_eq!(active_isa(), outer);
    }

    #[test]
    fn with_scratch_reenters_without_panic() {
        let n = with_scratch(|outer| {
            let v = outer.take_pool(0, 4);
            // nested use takes a fresh arena instead of panicking
            let inner_len = with_scratch(|inner| inner.take_pool(0, 2).len());
            outer.put_pool(0, v);
            inner_len
        });
        assert_eq!(n, 2);
    }

    #[test]
    fn pool_slots_are_independent_and_zeroed() {
        with_scratch(|s| {
            let mut a = s.take_pool(0, 3);
            a[0] = 7.0;
            let b = s.take_pool(1, 3);
            assert_eq!(b, vec![0.0; 3]);
            s.put_pool(0, a);
            s.put_pool(1, b);
            let a2 = s.take_pool(0, 3);
            assert_eq!(a2, vec![0.0; 3], "reused buffers must be re-zeroed");
            s.put_pool(0, a2);
        });
    }
}
