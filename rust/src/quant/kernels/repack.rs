//! SIMD-friendly interleaved repack of the bit-plane storage.
//!
//! `PackedMatrix` keeps its planes plane-major (`[bits][d_in/8][d_out]`)
//! — natural for serialization and byte-for-byte python parity, but a
//! fused kernel walking one byte-row of inputs then needs `bits` widely
//! strided streams. The repack interleaves the planes by byte-row and
//! pads the column axis to the vector width:
//!
//! ```text
//! data[(byte_row * bits + plane) * dp + o],   dp = round_up(d_out, 8)
//! ```
//!
//! so the kernel streams one contiguous run per (byte-row, plane) and can
//! always issue full 8-wide loads/stores on repacked data. Group scales
//! and zero-points (the binary α, respectively) are re-padded the same
//! way; padded columns carry **zero scale**, so they dequantize to 0 and
//! are safe to multiply-accumulate into padded scratch.
//!
//! Computed once at pack/load time and cached on the owning matrix in a
//! `OnceLock` — the canonical plane bytes stay the wire/python format,
//! this copy exists purely for the kernels.

/// The interleaved, padded copy of a packed (or binary) operand.
#[derive(Clone, Debug)]
pub struct Repacked {
    /// `d_out` rounded up to a multiple of 8 (the f32 SIMD lane count).
    pub dp: usize,
    /// `[d_in/8, bits, dp]` interleaved plane bytes (binary: `bits = 1`).
    pub data: Vec<u8>,
    /// `[d_in/group, dp]` group scales (binary: `[dp]` α), zero-padded.
    pub scales: Vec<f32>,
    /// `[d_in/group, dp]` group zero-points (binary: empty), zero-padded.
    pub zeros: Vec<f32>,
}

impl Repacked {
    /// Interleave a `PackedMatrix`'s plane-major storage.
    pub fn from_planes(
        planes: &[u8],
        bits: usize,
        d_in: usize,
        d_out: usize,
        scales: &[f32],
        zeros: &[f32],
        group: usize,
    ) -> Repacked {
        assert_eq!(d_in % 8, 0, "d_in must be a multiple of 8");
        assert_eq!(d_in % group, 0, "d_in must be a multiple of group");
        let rows = d_in / 8;
        assert_eq!(planes.len(), bits * rows * d_out);
        let n_groups = d_in / group;
        let dp = pad8(d_out);
        let mut data = vec![0u8; rows * bits * dp];
        for p in 0..bits {
            let plane = &planes[p * rows * d_out..][..rows * d_out];
            for br in 0..rows {
                let dst = (br * bits + p) * dp;
                data[dst..dst + d_out].copy_from_slice(&plane[br * d_out..][..d_out]);
            }
        }
        Repacked {
            dp,
            data,
            scales: pad_rows(scales, n_groups, d_out, dp),
            zeros: pad_rows(zeros, n_groups, d_out, dp),
        }
    }

    /// Pad a `BinaryMatrix`'s single plane; α rides in `scales`.
    pub fn from_binary(plane: &[u8], d_in: usize, d_out: usize, alpha: &[f32]) -> Repacked {
        assert_eq!(d_in % 8, 0, "d_in must be a multiple of 8");
        let rows = d_in / 8;
        assert_eq!(plane.len(), rows * d_out);
        assert_eq!(alpha.len(), d_out);
        let dp = pad8(d_out);
        let mut data = vec![0u8; rows * dp];
        for br in 0..rows {
            data[br * dp..br * dp + d_out].copy_from_slice(&plane[br * d_out..][..d_out]);
        }
        Repacked { dp, data, scales: pad_rows(alpha, 1, d_out, dp), zeros: Vec::new() }
    }

    /// Repacked footprint in bytes — diagnostics only; the paper's memory
    /// accounting (`nbytes`) stays on the canonical packed form.
    pub fn nbytes(&self) -> usize {
        self.data.len() + (self.scales.len() + self.zeros.len()) * 4
    }
}

fn pad8(n: usize) -> usize {
    n.div_ceil(8) * 8
}

fn pad_rows(src: &[f32], rows: usize, d_out: usize, dp: usize) -> Vec<f32> {
    assert_eq!(src.len(), rows * d_out);
    let mut out = vec![0.0f32; rows * dp];
    for r in 0..rows {
        out[r * dp..r * dp + d_out].copy_from_slice(&src[r * d_out..][..d_out]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interleave_and_pad() {
        // 2-bit, d_in = 8 (1 byte-row), d_out = 3 → dp = 8
        let planes = vec![0x11, 0x22, 0x33, 0x44, 0x55, 0x66]; // [2][1][3]
        let rp = Repacked::from_planes(
            &planes,
            2,
            8,
            3,
            &[1.0, 2.0, 3.0],
            &[4.0, 5.0, 6.0],
            8,
        );
        assert_eq!(rp.dp, 8);
        // byte-row 0: plane 0 bytes then plane 1 bytes, each padded to 8
        assert_eq!(&rp.data[0..3], &[0x11, 0x22, 0x33]);
        assert_eq!(&rp.data[3..8], &[0; 5]);
        assert_eq!(&rp.data[8..11], &[0x44, 0x55, 0x66]);
        assert_eq!(&rp.scales[..4], &[1.0, 2.0, 3.0, 0.0]);
        assert_eq!(&rp.zeros[..4], &[4.0, 5.0, 6.0, 0.0]);
    }

    #[test]
    fn binary_alpha_padded() {
        let rp = Repacked::from_binary(&[0xAB, 0xCD], 16, 1, &[0.5]);
        assert_eq!(rp.dp, 8);
        assert_eq!(rp.data.len(), 16);
        assert_eq!(rp.data[0], 0xAB);
        assert_eq!(rp.data[8], 0xCD);
        assert_eq!(rp.scales.len(), 8);
        assert_eq!(rp.scales[0], 0.5);
        assert_eq!(rp.scales[1], 0.0);
        assert!(rp.zeros.is_empty());
    }
}
