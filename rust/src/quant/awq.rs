//! AWQ-style activation-aware weight scaling (Lin et al. 2024, paper
//! ref. \[26\]) — one of the PTQ backends the paper declares PMQ orthogonal
//! to (§3.2.3: "Current PTQ methods \[14\], \[26\] … can be deployed for
//! MC#"). This module makes that claim executable: the PMQ allocation can
//! drive RTN, GPTQ *or* AWQ per-expert quantization and the ablation
//! bench (`ablation_ptq`) compares them.
//!
//! AWQ's core observation: a small fraction of weight channels are
//! *salient* because their input activations are large; scaling those
//! channels **up** before quantization (and the activations down by the
//! same factor at runtime) shrinks their relative quantization error.
//! Per input channel `i`:
//!
//! ```text
//! s_i = (mag_i / geomean(mag))^α,   mag_i = E[|x_i|]
//! Ŵ  = Q(diag(s) · W)              stored packed
//! y   = (x ⊘ s) · Ŵ                 at runtime (inv_s folded into matvec)
//! ```
//!
//! α is grid-searched per matrix to minimize the activation-space
//! reconstruction error on calibration rows — exactly the AWQ recipe,
//! with our group-wise RTN as the inner quantizer.

use crate::tensor::Tensor2;

use super::packed::PackedMatrix;
use super::qlinear::QuantLinear;
use super::rtn;

/// The α grid AWQ searches (0 = plain RTN, 1 = fully activation-scaled).
pub const ALPHA_GRID: [f32; 9] =
    [0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0];

/// Mean absolute activation per input channel over calibration rows.
pub fn channel_mags(xs: &[Vec<f32>], d_in: usize) -> Vec<f32> {
    let mut mags = vec![0.0f32; d_in];
    if xs.is_empty() {
        return vec![1.0; d_in];
    }
    for x in xs {
        for (m, &v) in mags.iter_mut().zip(x) {
            *m += v.abs();
        }
    }
    let inv = 1.0 / xs.len() as f32;
    for m in mags.iter_mut() {
        *m = (*m * inv).max(1e-6);
    }
    mags
}

/// Per-channel scales for a given α, normalized so geomean(s) = 1 (keeps
/// the overall weight magnitude — and the min/max quantization grids —
/// in the same range as the unscaled matrix).
pub fn scales_for_alpha(mags: &[f32], alpha: f32) -> Vec<f32> {
    let log_gm: f32 =
        mags.iter().map(|&m| m.ln()).sum::<f32>() / mags.len() as f32;
    let gm = log_gm.exp();
    mags.iter().map(|&m| (m / gm).powf(alpha).clamp(1e-3, 1e3)).collect()
}

/// Activation-space squared reconstruction error of `x·W ≈ (x⊘s)·Ŵ` over
/// sample rows.
fn recon_err(xs: &[Vec<f32>], w: &Tensor2, w_hat_unscaled: &Tensor2) -> f64 {
    // `w_hat_unscaled` is already diag(1/s)·Ŵ, i.e. the effective weights;
    // compare x·W vs x·W_eff directly.
    let d_out = w.cols;
    let mut err = 0.0f64;
    for x in xs {
        for o in 0..d_out {
            let mut a = 0.0f32;
            let mut b = 0.0f32;
            for (r, &xr) in x.iter().enumerate() {
                a += xr * w.at(r, o);
                b += xr * w_hat_unscaled.at(r, o);
            }
            err += ((a - b) as f64).powi(2);
        }
    }
    err
}

/// Quantize one matrix with AWQ scaling: grid-search α on a subsample of
/// calibration rows, return `(best_alpha, QuantLinear::Scaled)`. `bits`
/// must be ≥ 2 (1-bit binarization is scale-invariant per channel — the
/// sign pattern of `diag(s)·W` equals that of `W` — so AWQ degenerates
/// to plain binarization there and callers should use it directly).
pub fn awq_quantize(
    w: &Tensor2,
    xs: &[Vec<f32>],
    bits: u8,
    group: usize,
) -> (f32, QuantLinear) {
    assert!(bits >= 2, "AWQ needs a linear quantizer (bits >= 2)");
    let d_in = w.rows;
    let mags = channel_mags(xs, d_in);
    // error probe on a bounded subsample to keep the grid search cheap
    let probe: Vec<Vec<f32>> = xs.iter().take(32).cloned().collect();
    let mut best: Option<(f32, f64, PackedMatrix, Vec<f32>)> = None;
    for &alpha in &ALPHA_GRID {
        let s = scales_for_alpha(&mags, alpha);
        // scale rows of W up by s
        let mut ws = w.clone();
        for r in 0..d_in {
            let sr = s[r];
            for v in ws.row_mut(r) {
                *v *= sr;
            }
        }
        let (c, sc, z) = rtn::quantize_rtn(&ws, bits, group);
        let pm = PackedMatrix::from_codes(&c, sc, z, ws.rows, ws.cols, bits, group);
        // effective reconstruction: diag(1/s) · dequant(pm)
        let mut w_eff = pm.dequantize();
        for r in 0..d_in {
            let inv = 1.0 / s[r];
            for v in w_eff.row_mut(r) {
                *v *= inv;
            }
        }
        let err = recon_err(&probe, w, &w_eff);
        if best.as_ref().map(|b| err < b.1).unwrap_or(true) {
            let inv_s: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
            best = Some((alpha, err, pm, inv_s));
        }
    }
    let (alpha, _, pm, inv_s) = best.unwrap();
    (alpha, QuantLinear::Scaled { inv_s, inner: pm })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Calibration rows where a few channels carry much larger
    /// activations — the regime AWQ is built for.
    fn salient_acts(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| {
                (0..d)
                    .map(|i| {
                        let boost = if i % 16 == 0 { 12.0 } else { 1.0 };
                        boost * rng.normal()
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn channel_mags_reflect_salience() {
        let mut rng = Rng::new(40);
        let xs = salient_acts(&mut rng, 64, 32);
        let mags = channel_mags(&xs, 32);
        // boosted channels (0, 16) should dominate the others
        let hot = (mags[0] + mags[16]) / 2.0;
        let cold: f32 =
            (1..32).filter(|&i| i != 16).map(|i| mags[i]).sum::<f32>() / 30.0;
        assert!(hot > 4.0 * cold, "hot {hot} cold {cold}");
    }

    #[test]
    fn scales_geomean_normalized() {
        let mut rng = Rng::new(41);
        let xs = salient_acts(&mut rng, 32, 64);
        let mags = channel_mags(&xs, 64);
        for &a in &[0.25f32, 0.5, 1.0] {
            let s = scales_for_alpha(&mags, a);
            let log_gm: f32 = s.iter().map(|v| v.ln()).sum::<f32>() / 64.0;
            assert!(log_gm.abs() < 0.05, "alpha {a}: log-geomean {log_gm}");
            assert!(s.iter().all(|&v| v > 0.0));
        }
    }

    #[test]
    fn awq_beats_plain_rtn_on_salient_activations() {
        let mut rng = Rng::new(42);
        let (d_in, d_out) = (64, 24);
        let w = Tensor2::randn(d_in, d_out, &mut rng, 1.0);
        let xs = salient_acts(&mut rng, 96, d_in);
        for bits in [2u8, 3] {
            let (_, ql) = awq_quantize(&w, &xs, bits, 32);
            let awq_err = recon_err(&xs, &w, &ql.dequantize());
            let rtn_hat = rtn::fake_quant(&w, bits, 32);
            let rtn_err = recon_err(&xs, &w, &rtn_hat);
            assert!(
                awq_err <= rtn_err,
                "bits={bits}: awq {awq_err:.3} !<= rtn {rtn_err:.3}"
            );
        }
    }

    #[test]
    fn scaled_matvec_matches_dequant_reference() {
        let mut rng = Rng::new(43);
        let w = Tensor2::randn(64, 16, &mut rng, 1.0);
        let xs = salient_acts(&mut rng, 48, 64);
        let (_, ql) = awq_quantize(&w, &xs, 3, 32);
        let wd = ql.dequantize();
        let x = &xs[0];
        let mut want = vec![0.0f32; 16];
        for (r, &xr) in x.iter().enumerate() {
            for o in 0..16 {
                want[o] += xr * wd.at(r, o);
            }
        }
        let mut got = vec![0.0f32; 16];
        ql.matvec_acc(x, &mut got);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn alpha_zero_is_plain_rtn() {
        let mags = vec![1.0f32; 32];
        let s = scales_for_alpha(&mags, 0.77);
        assert!(s.iter().all(|&v| (v - 1.0).abs() < 1e-6));
    }
}
