//! Bit-plane packed weight storage — the Rust half of the format defined
//! in `python/compile/kernels/packing.py` (see its module docstring for
//! the layout). `PackedMatrix` is what actually sits in "device" memory
//! at serve time: `bits × d_in/8 × d_out` bytes of planes plus group
//! scale/zero vectors; this is the paper's pre-loading compression.
//!
//! `matvec_fused`/`matmul_fused` dequantize on the fly inside the
//! mat-vec/mat-mul — the native-backend analog of the Pallas
//! dequant-matmul kernel (and of the paper's HQQ ATEN path). Since the
//! kernel-layer refactor both delegate to `quant::kernels`, which
//! ISA-dispatches between the AVX2+FMA and portable scalar kernels over
//! an interleaved repack of these planes (computed once at pack/load
//! time, cached here). A cross-language test pins the plane bytes
//! against the python fixed vectors.

use std::sync::OnceLock;

use crate::tensor::Tensor2;

use super::kernels::{self, Repacked};

#[derive(Clone, Debug)]
pub struct PackedMatrix {
    pub d_in: usize,
    pub d_out: usize,
    pub bits: u8,
    pub group: usize,
    /// `bits` planes, each `d_in/8 * d_out` bytes (row-major `[d_in/8, d_out]`).
    pub planes: Vec<u8>,
    /// `[d_in/group, d_out]` group scales.
    pub scales: Vec<f32>,
    /// `[d_in/group, d_out]` group zero-points.
    pub zeros: Vec<f32>,
    /// Kernel-layer interleaved repack (see `quant::kernels::repack`),
    /// built eagerly at pack/load time; `OnceLock` keeps late
    /// construction paths (and `Clone`) sound under shared access.
    repack: OnceLock<Repacked>,
}

impl PackedMatrix {
    /// Pack integer codes (from RTN or GPTQ) into bit-planes.
    pub fn from_codes(
        codes: &[u8],
        scales: Vec<f32>,
        zeros: Vec<f32>,
        d_in: usize,
        d_out: usize,
        bits: u8,
        group: usize,
    ) -> PackedMatrix {
        assert_eq!(d_in % 8, 0, "d_in must be multiple of 8");
        assert_eq!(codes.len(), d_in * d_out);
        let rows = d_in / 8;
        let mut planes = vec![0u8; bits as usize * rows * d_out];
        for p in 0..bits as usize {
            let plane = &mut planes[p * rows * d_out..(p + 1) * rows * d_out];
            for r in 0..d_in {
                let byte_row = r / 8;
                let bit = (r % 8) as u8;
                for o in 0..d_out {
                    let b = (codes[r * d_out + o] >> p) & 1;
                    plane[byte_row * d_out + o] |= b << bit;
                }
            }
        }
        PackedMatrix::from_parts(planes, scales, zeros, d_in, d_out, bits, group)
    }

    /// Assemble from already-packed planes (checkpoint load path) and
    /// build the kernel repack once, up front.
    pub fn from_parts(
        planes: Vec<u8>,
        scales: Vec<f32>,
        zeros: Vec<f32>,
        d_in: usize,
        d_out: usize,
        bits: u8,
        group: usize,
    ) -> PackedMatrix {
        let pm = PackedMatrix {
            d_in,
            d_out,
            bits,
            group,
            planes,
            scales,
            zeros,
            repack: OnceLock::new(),
        };
        let _ = pm.repacked();
        pm
    }

    /// The kernel layer's interleaved repack of the planes.
    pub fn repacked(&self) -> &Repacked {
        self.repack.get_or_init(|| {
            Repacked::from_planes(
                &self.planes,
                self.bits as usize,
                self.d_in,
                self.d_out,
                &self.scales,
                &self.zeros,
                self.group,
            )
        })
    }

    /// Unpack back to integer codes (tests / PJRT literal staging).
    pub fn unpack_codes(&self) -> Vec<u8> {
        let rows = self.d_in / 8;
        let mut codes = vec![0u8; self.d_in * self.d_out];
        for p in 0..self.bits as usize {
            let plane = &self.planes[p * rows * self.d_out..(p + 1) * rows * self.d_out];
            for r in 0..self.d_in {
                let row = &plane[(r / 8) * self.d_out..][..self.d_out];
                let bit = (r % 8) as u8;
                for o in 0..self.d_out {
                    codes[r * self.d_out + o] |= ((row[o] >> bit) & 1) << p;
                }
            }
        }
        codes
    }

    /// Full dequantization to f32 (tests, ε-table probes).
    pub fn dequantize(&self) -> Tensor2 {
        let codes = self.unpack_codes();
        super::rtn::dequantize(&codes, &self.scales, &self.zeros, self.d_in, self.d_out, self.group)
    }

    /// Fused dequant mat-vec: `y += x @ dequant(self)` without ever
    /// materializing the f32 weight matrix (kernel layer, thread-local
    /// scratch).
    pub fn matvec_fused(&self, x: &[f32], y: &mut [f32]) {
        kernels::with_scratch(|s| kernels::packed_matvec(self, x, y, s));
    }

    /// Batched `y += x @ dequant(self)` over a token block: each group's
    /// weight tile is dequantized to scratch **once** and reused by all
    /// `T` tokens — the amortization the Pallas kernel gets by keeping
    /// the `[T, d_in]` activation block VMEM-resident while weight tiles
    /// stream through.
    pub fn matmul_fused(&self, x: &Tensor2, y: &mut Tensor2) {
        assert_eq!(x.cols, self.d_in);
        assert_eq!((y.rows, y.cols), (x.rows, self.d_out));
        kernels::with_scratch(|s| kernels::packed_matmul(self, &x.data, x.rows, &mut y.data, s));
    }

    /// Packed storage footprint in bytes (planes + quantizer params) —
    /// the quantity Tables 5/8 account.
    pub fn nbytes(&self) -> u64 {
        (self.planes.len() + (self.scales.len() + self.zeros.len()) * 4) as u64
    }

    /// Effective bits per weight including quantizer params.
    pub fn bits_per_weight(&self) -> f64 {
        self.nbytes() as f64 * 8.0 / (self.d_in * self.d_out) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::quantize_rtn;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn fixed_vector_matches_python() {
        // mirror of python/tests/test_packing.py::test_pack_fixed_vector
        let codes: Vec<u8> = (0..16).map(|i| (i % 4) as u8).collect();
        let pm = PackedMatrix::from_codes(&codes, vec![1.0; 1], vec![0.0; 1], 16, 1, 2, 16);
        let rows = 2;
        assert_eq!(pm.planes[0], 0xAA); // plane 0, byte row 0
        assert_eq!(pm.planes[1], 0xAA);
        assert_eq!(pm.planes[rows], 0xCC); // plane 1 starts at rows*d_out
        assert_eq!(pm.planes[rows + 1], 0xCC);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        prop::for_all(71, 25, |rng, _| {
            let bits = 1 + rng.below(4) as u8;
            let d_in = prop::dim(rng, 32, 128, 32);
            let d_out = 1 + rng.below(24);
            let codes: Vec<u8> =
                (0..d_in * d_out).map(|_| (rng.below(1 << bits)) as u8).collect();
            let g = d_in / 32;
            let pm = PackedMatrix::from_codes(
                &codes,
                vec![1.0; g * d_out],
                vec![0.0; g * d_out],
                d_in,
                d_out,
                bits,
                32,
            );
            assert_eq!(pm.unpack_codes(), codes);
        });
    }

    #[test]
    fn fused_matvec_matches_dequant_matmul() {
        prop::for_all(72, 15, |rng, _| {
            let bits = 2 + rng.below(3) as u8;
            let d_in = prop::dim(rng, 32, 96, 32);
            let d_out = 1 + rng.below(32);
            let w = Tensor2::randn(d_in, d_out, rng, 1.0);
            let (codes, scales, zeros) = quantize_rtn(&w, bits, 32);
            let pm = PackedMatrix::from_codes(&codes, scales, zeros, d_in, d_out, bits, 32);
            let x: Vec<f32> = (0..d_in).map(|_| rng.normal()).collect();
            let w_hat = pm.dequantize();
            let mut want = vec![0.0f32; d_out];
            for (r, &xr) in x.iter().enumerate() {
                for o in 0..d_out {
                    want[o] += xr * w_hat.at(r, o);
                }
            }
            let mut got = vec![0.0f32; d_out];
            pm.matvec_fused(&x, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn batched_matmul_matches_row_matvecs() {
        prop::for_all(73, 15, |rng, _| {
            let bits = 2 + rng.below(3) as u8;
            let d_in = prop::dim(rng, 32, 96, 32);
            let d_out = 1 + rng.below(32);
            let t = 1 + rng.below(6);
            let w = Tensor2::randn(d_in, d_out, rng, 1.0);
            let (codes, scales, zeros) = quantize_rtn(&w, bits, 32);
            let pm = PackedMatrix::from_codes(&codes, scales, zeros, d_in, d_out, bits, 32);
            let x = Tensor2::randn(t, d_in, rng, 1.0);
            let mut got = Tensor2::zeros(t, d_out);
            pm.matmul_fused(&x, &mut got);
            for ti in 0..t {
                let mut want = vec![0.0f32; d_out];
                pm.matvec_fused(x.row(ti), &mut want);
                for (a, b) in got.row(ti).iter().zip(&want) {
                    assert!((a - b).abs() < 1e-3, "row {ti}: {a} vs {b}");
                }
            }
        });
    }

    #[test]
    fn bits_accounting() {
        let mut rng = Rng::new(10);
        let w = Tensor2::randn(128, 64, &mut rng, 1.0);
        let (codes, scales, zeros) = quantize_rtn(&w, 2, 32);
        let pm = PackedMatrix::from_codes(&codes, scales, zeros, 128, 64, 2, 32);
        // 2 bits + 2*32/32 f32 params per 32-weight group column =
        // 2 + 64/32 * ... => bits/weight = 2 + (2*4*8)/32 = 4 per group? No:
        // per weight: planes 2 bits, params (4+4 bytes)/(32 weights) = 2 bits.
        assert!((pm.bits_per_weight() - 4.0).abs() < 0.01, "{}", pm.bits_per_weight());
    }
}
