//! Bit-plane packed weight storage — the Rust half of the format defined
//! in `python/compile/kernels/packing.py` (see its module docstring for
//! the layout). `PackedMatrix` is what actually sits in "device" memory
//! at serve time: `bits × d_in/8 × d_out` bytes of planes plus group
//! scale/zero vectors; this is the paper's pre-loading compression.
//!
//! `matvec_fused` dequantizes on the fly inside the mat-vec — the
//! native-backend analog of the Pallas dequant-matmul kernel (and of the
//! paper's HQQ ATEN path). A cross-language test pins the plane bytes
//! against the python fixed vectors.

use crate::tensor::Tensor2;

#[derive(Clone, Debug)]
pub struct PackedMatrix {
    pub d_in: usize,
    pub d_out: usize,
    pub bits: u8,
    pub group: usize,
    /// `bits` planes, each `d_in/8 * d_out` bytes (row-major `[d_in/8, d_out]`).
    pub planes: Vec<u8>,
    /// `[d_in/group, d_out]` group scales.
    pub scales: Vec<f32>,
    /// `[d_in/group, d_out]` group zero-points.
    pub zeros: Vec<f32>,
}

impl PackedMatrix {
    /// Pack integer codes (from RTN or GPTQ) into bit-planes.
    pub fn from_codes(
        codes: &[u8],
        scales: Vec<f32>,
        zeros: Vec<f32>,
        d_in: usize,
        d_out: usize,
        bits: u8,
        group: usize,
    ) -> PackedMatrix {
        assert_eq!(d_in % 8, 0, "d_in must be multiple of 8");
        assert_eq!(codes.len(), d_in * d_out);
        let rows = d_in / 8;
        let mut planes = vec![0u8; bits as usize * rows * d_out];
        for p in 0..bits as usize {
            let plane = &mut planes[p * rows * d_out..(p + 1) * rows * d_out];
            for r in 0..d_in {
                let byte_row = r / 8;
                let bit = (r % 8) as u8;
                for o in 0..d_out {
                    let b = (codes[r * d_out + o] >> p) & 1;
                    plane[byte_row * d_out + o] |= b << bit;
                }
            }
        }
        PackedMatrix { d_in, d_out, bits, group, planes, scales, zeros }
    }

    /// Unpack back to integer codes (tests / PJRT literal staging).
    pub fn unpack_codes(&self) -> Vec<u8> {
        let rows = self.d_in / 8;
        let mut codes = vec![0u8; self.d_in * self.d_out];
        for p in 0..self.bits as usize {
            let plane = &self.planes[p * rows * self.d_out..(p + 1) * rows * self.d_out];
            for r in 0..self.d_in {
                let byte = plane[(r / 8) * self.d_out..][..self.d_out].to_vec();
                let bit = (r % 8) as u8;
                for o in 0..self.d_out {
                    codes[r * self.d_out + o] |= ((byte[o] >> bit) & 1) << p;
                }
            }
        }
        codes
    }

    /// Full dequantization to f32 (tests, ε-table probes).
    pub fn dequantize(&self) -> Tensor2 {
        let codes = self.unpack_codes();
        super::rtn::dequantize(&codes, &self.scales, &self.zeros, self.d_in, self.d_out, self.group)
    }

    /// Fused dequant mat-vec: `y += x @ dequant(self)` without ever
    /// materializing the f32 weight matrix. Walks plane bytes row-group
    /// by row-group so the packed bytes stream linearly; each byte (8
    /// rows of one column, one plane) indexes a precomputed 0/1 expansion
    /// so the inner loop is pure FMAs (no per-element shifts — the CPU
    /// analog of the Pallas kernel's vectorized unpack).
    pub fn matvec_fused(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.d_in);
        assert_eq!(y.len(), self.d_out);
        let rows = self.d_in / 8;
        let d_out = self.d_out;
        let bits = self.bits as usize;
        // accumulate q-weighted x per output column in group chunks so the
        // affine (q - z) * s applies once per group
        let g = self.group;
        let n_groups = self.d_in / g;
        let bytes_per_group = g / 8;
        let mut qacc = vec![0.0f32; d_out]; // Σ_r x_r * q[r, o] within group
        for gi in 0..n_groups {
            qacc.fill(0.0);
            let mut xsum = 0.0f32; // Σ_r x_r within group (for the -z*s term)
            for bq in 0..bytes_per_group {
                let byte_row = gi * bytes_per_group + bq;
                let x8 = &x[byte_row * 8..byte_row * 8 + 8];
                if x8.iter().all(|&v| v == 0.0) {
                    continue;
                }
                xsum += x8.iter().sum::<f32>();
                for (p, pw) in PLANE_WEIGHTS[..bits].iter().enumerate() {
                    let plane = &self.planes[p * rows * d_out + byte_row * d_out..][..d_out];
                    // pre-scale the token slice by the plane weight once
                    let xw = [
                        x8[0] * pw,
                        x8[1] * pw,
                        x8[2] * pw,
                        x8[3] * pw,
                        x8[4] * pw,
                        x8[5] * pw,
                        x8[6] * pw,
                        x8[7] * pw,
                    ];
                    for o in 0..d_out {
                        let l = &BIT_LUT[plane[o] as usize];
                        qacc[o] += l[0] * xw[0]
                            + l[1] * xw[1]
                            + l[2] * xw[2]
                            + l[3] * xw[3]
                            + l[4] * xw[4]
                            + l[5] * xw[5]
                            + l[6] * xw[6]
                            + l[7] * xw[7];
                    }
                }
            }
            let srow = &self.scales[gi * d_out..][..d_out];
            let zrow = &self.zeros[gi * d_out..][..d_out];
            for o in 0..d_out {
                y[o] += srow[o] * (qacc[o] - zrow[o] * xsum);
            }
        }
    }

    /// Batched `y += x @ dequant(self)` over a token block: each group's
    /// weight tile is dequantized to f32 scratch **once** and reused by
    /// all `T` tokens — the amortization the Pallas kernel gets by keeping
    /// the `[T, d_in]` activation block VMEM-resident while weight tiles
    /// stream through.
    pub fn matmul_fused(&self, x: &Tensor2, y: &mut Tensor2) {
        assert_eq!(x.cols, self.d_in);
        assert_eq!((y.rows, y.cols), (x.rows, self.d_out));
        let rows = self.d_in / 8;
        let d_out = self.d_out;
        let bits = self.bits as usize;
        let g = self.group;
        let t = x.rows;
        let mut tile = vec![0.0f32; g * d_out]; // dequantized [g, d_out]
        for gi in 0..self.d_in / g {
            // decode this group's rows once
            let srow = &self.scales[gi * d_out..][..d_out];
            let zrow = &self.zeros[gi * d_out..][..d_out];
            for rq in 0..g {
                let r = gi * g + rq;
                let byte_row = r / 8;
                let bit = r % 8;
                let trow = &mut tile[rq * d_out..(rq + 1) * d_out];
                trow.fill(0.0);
                for (p, pw) in PLANE_WEIGHTS[..bits].iter().enumerate() {
                    let plane = &self.planes[p * rows * d_out + byte_row * d_out..][..d_out];
                    for o in 0..d_out {
                        trow[o] += pw * ((plane[o] >> bit) & 1) as f32;
                    }
                }
                for o in 0..d_out {
                    trow[o] = srow[o] * (trow[o] - zrow[o]);
                }
            }
            // every token reuses the decoded tile
            for ti in 0..t {
                let xr = &x.row(ti)[gi * g..(gi + 1) * g];
                let yrow = y.row_mut(ti);
                for (rq, &xv) in xr.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let trow = &tile[rq * d_out..(rq + 1) * d_out];
                    for (a, &w) in yrow.iter_mut().zip(trow) {
                        *a += xv * w;
                    }
                }
            }
        }
    }

    /// Packed storage footprint in bytes (planes + quantizer params) —
    /// the quantity Tables 5/8 account.
    pub fn nbytes(&self) -> u64 {
        (self.planes.len() + (self.scales.len() + self.zeros.len()) * 4) as u64
    }

    /// Effective bits per weight including quantizer params.
    pub fn bits_per_weight(&self) -> f64 {
        self.nbytes() as f64 * 8.0 / (self.d_in * self.d_out) as f64
    }
}

/// 2^p weights for plane accumulation.
const PLANE_WEIGHTS: [f32; 4] = [1.0, 2.0, 4.0, 8.0];

/// `[byte] -> [0/1; 8]` expansion: bit j of a plane byte is the code bit
/// of input row `8·byte_row + j`.
static BIT_LUT: [[f32; 8]; 256] = make_bit_lut();

const fn make_bit_lut() -> [[f32; 8]; 256] {
    let mut l = [[0.0f32; 8]; 256];
    let mut b = 0;
    while b < 256 {
        let mut j = 0;
        while j < 8 {
            if (b >> j) & 1 == 1 {
                l[b][j] = 1.0;
            }
            j += 1;
        }
        b += 1;
    }
    l
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::quantize_rtn;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn fixed_vector_matches_python() {
        // mirror of python/tests/test_packing.py::test_pack_fixed_vector
        let codes: Vec<u8> = (0..16).map(|i| (i % 4) as u8).collect();
        let pm = PackedMatrix::from_codes(&codes, vec![1.0; 1], vec![0.0; 1], 16, 1, 2, 16);
        let rows = 2;
        assert_eq!(pm.planes[0], 0xAA); // plane 0, byte row 0
        assert_eq!(pm.planes[1], 0xAA);
        assert_eq!(pm.planes[rows], 0xCC); // plane 1 starts at rows*d_out
        assert_eq!(pm.planes[rows + 1], 0xCC);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        prop::for_all(71, 25, |rng, _| {
            let bits = 1 + rng.below(4) as u8;
            let d_in = prop::dim(rng, 32, 128, 32);
            let d_out = 1 + rng.below(24);
            let codes: Vec<u8> =
                (0..d_in * d_out).map(|_| (rng.below(1 << bits)) as u8).collect();
            let g = d_in / 32;
            let pm = PackedMatrix::from_codes(
                &codes,
                vec![1.0; g * d_out],
                vec![0.0; g * d_out],
                d_in,
                d_out,
                bits,
                32,
            );
            assert_eq!(pm.unpack_codes(), codes);
        });
    }

    #[test]
    fn fused_matvec_matches_dequant_matmul() {
        prop::for_all(72, 15, |rng, _| {
            let bits = 2 + rng.below(3) as u8;
            let d_in = prop::dim(rng, 32, 96, 32);
            let d_out = 1 + rng.below(32);
            let w = Tensor2::randn(d_in, d_out, rng, 1.0);
            let (codes, scales, zeros) = quantize_rtn(&w, bits, 32);
            let pm = PackedMatrix::from_codes(&codes, scales, zeros, d_in, d_out, bits, 32);
            let x: Vec<f32> = (0..d_in).map(|_| rng.normal()).collect();
            let w_hat = pm.dequantize();
            let mut want = vec![0.0f32; d_out];
            for (r, &xr) in x.iter().enumerate() {
                for o in 0..d_out {
                    want[o] += xr * w_hat.at(r, o);
                }
            }
            let mut got = vec![0.0f32; d_out];
            pm.matvec_fused(&x, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn batched_matmul_matches_row_matvecs() {
        prop::for_all(73, 15, |rng, _| {
            let bits = 2 + rng.below(3) as u8;
            let d_in = prop::dim(rng, 32, 96, 32);
            let d_out = 1 + rng.below(32);
            let t = 1 + rng.below(6);
            let w = Tensor2::randn(d_in, d_out, rng, 1.0);
            let (codes, scales, zeros) = quantize_rtn(&w, bits, 32);
            let pm = PackedMatrix::from_codes(&codes, scales, zeros, d_in, d_out, bits, 32);
            let x = Tensor2::randn(t, d_in, rng, 1.0);
            let mut got = Tensor2::zeros(t, d_out);
            pm.matmul_fused(&x, &mut got);
            for ti in 0..t {
                let mut want = vec![0.0f32; d_out];
                pm.matvec_fused(x.row(ti), &mut want);
                for (a, b) in got.row(ti).iter().zip(&want) {
                    assert!((a - b).abs() < 1e-3, "row {ti}: {a} vs {b}");
                }
            }
        });
    }

    #[test]
    fn bits_accounting() {
        let mut rng = Rng::new(10);
        let w = Tensor2::randn(128, 64, &mut rng, 1.0);
        let (codes, scales, zeros) = quantize_rtn(&w, 2, 32);
        let pm = PackedMatrix::from_codes(&codes, scales, zeros, 128, 64, 2, 32);
        // 2 bits + 2*32/32 f32 params per 32-weight group column =
        // 2 + 64/32 * ... => bits/weight = 2 + (2*4*8)/32 = 4 per group? No:
        // per weight: planes 2 bits, params (4+4 bytes)/(32 weights) = 2 bits.
        assert!((pm.bits_per_weight() - 4.0).abs() < 0.01, "{}", pm.bits_per_weight());
    }
}
