//! Row-major f32 matrix substrate.
//!
//! Everything in the model, trainer and quantizers runs on [`Tensor2`]:
//! a flat `Vec<f32>` with (rows, cols). The matmul kernels here are the
//! native-backend hot path — `matmul` is blocked over K with an
//! 8-wide-unrolled inner loop so the release build autovectorizes it
//! (see EXPERIMENTS.md §Perf for the measured effect).

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor2 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor2 {
    pub fn zeros(rows: usize, cols: usize) -> Tensor2 {
        Tensor2 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Tensor2 {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Tensor2 { rows, cols, data }
    }

    /// Kaiming-ish init: N(0, std²) with std = gain / sqrt(fan_in).
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng, std: f32) -> Tensor2 {
        Tensor2 { rows, cols, data: rng.normal_vec(rows * cols, std) }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// `self @ other` — blocked matmul, output written into a fresh tensor.
    pub fn matmul(&self, other: &Tensor2) -> Tensor2 {
        let mut out = Tensor2::zeros(self.rows, other.cols);
        matmul_into(self, other, &mut out);
        out
    }

    /// `self @ other^T`.
    pub fn matmul_t(&self, other: &Tensor2) -> Tensor2 {
        assert_eq!(self.cols, other.cols, "matmul_t inner dim");
        let mut out = Tensor2::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let a = self.row(i);
            let orow = out.row_mut(i);
            for j in 0..other.rows {
                orow[j] = dot(a, other.row(j));
            }
        }
        out
    }

    /// `self^T @ other` (used by backward passes for weight grads).
    pub fn t_matmul(&self, other: &Tensor2) -> Tensor2 {
        assert_eq!(self.rows, other.rows, "t_matmul outer dim");
        let mut out = Tensor2::zeros(self.cols, other.cols);
        for r in 0..self.rows {
            let a = self.row(r);
            let b = other.row(r);
            for (i, &ai) in a.iter().enumerate() {
                if ai == 0.0 {
                    continue;
                }
                let orow = out.row_mut(i);
                axpy(ai, b, orow);
            }
        }
        out
    }

    pub fn transpose(&self) -> Tensor2 {
        let mut out = Tensor2::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    pub fn add_assign(&mut self, other: &Tensor2) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Row-wise softmax in place.
    pub fn softmax_rows(&mut self) {
        for r in 0..self.rows {
            softmax(self.row_mut(r));
        }
    }

    /// Bytes of an f32 tensor (for memory accounting).
    pub fn nbytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }
}

/// `out = a @ b`, blocked over K for cache friendliness.
pub fn matmul_into(a: &Tensor2, b: &Tensor2, out: &mut Tensor2) {
    assert_eq!(a.cols, b.rows, "matmul inner dim {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    assert_eq!(out.rows, a.rows);
    assert_eq!(out.cols, b.cols);
    out.data.fill(0.0);
    const KB: usize = 64;
    let n = b.cols;
    for k0 in (0..a.cols).step_by(KB) {
        let k1 = (k0 + KB).min(a.cols);
        for i in 0..a.rows {
            let arow = a.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for k in k0..k1 {
                let aik = arow[k];
                if aik != 0.0 {
                    axpy(aik, &b.data[k * n..(k + 1) * n], orow);
                }
            }
        }
    }
}

/// `y += alpha * x`, 8-wide unrolled so LLVM vectorizes it.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    let chunks = n / 8;
    for c in 0..chunks {
        let i = c * 8;
        y[i] += alpha * x[i];
        y[i + 1] += alpha * x[i + 1];
        y[i + 2] += alpha * x[i + 2];
        y[i + 3] += alpha * x[i + 3];
        y[i + 4] += alpha * x[i + 4];
        y[i + 5] += alpha * x[i + 5];
        y[i + 6] += alpha * x[i + 6];
        y[i + 7] += alpha * x[i + 7];
    }
    for i in chunks * 8..n {
        y[i] += alpha * x[i];
    }
}

/// Dot product, 8-wide unrolled.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// Numerically-stable in-place softmax.
pub fn softmax(xs: &mut [f32]) {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// SiLU activation `x * sigmoid(x)`.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// d/dx silu(x).
#[inline]
pub fn silu_grad(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

/// Indices of the top-k values, descending (stable on ties by lower index).
pub fn top_k_indices(xs: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap().then(a.cmp(&b)));
    idx.truncate(k);
    idx
}

/// RMSNorm: `x * g / rms(x)` row-wise.
pub fn rmsnorm(x: &[f32], gain: &[f32], out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    for i in 0..x.len() {
        out[i] = x[i] * inv * gain[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn matmul_known() {
        let a = Tensor2::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor2::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_t_matches_transpose() {
        prop::for_all(11, 20, |rng, _| {
            let (m, k, n) = (1 + rng.below(8), 1 + rng.below(12), 1 + rng.below(8));
            let a = Tensor2::randn(m, k, rng, 1.0);
            let b = Tensor2::randn(n, k, rng, 1.0);
            let got = a.matmul_t(&b);
            let want = a.matmul(&b.transpose());
            for (g, w) in got.data.iter().zip(&want.data) {
                assert!((g - w).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn t_matmul_matches_transpose() {
        prop::for_all(12, 20, |rng, _| {
            let (m, k, n) = (1 + rng.below(8), 1 + rng.below(8), 1 + rng.below(8));
            let a = Tensor2::randn(m, k, rng, 1.0);
            let b = Tensor2::randn(m, n, rng, 1.0);
            let got = a.t_matmul(&b);
            let want = a.transpose().matmul(&b);
            for (g, w) in got.data.iter().zip(&want.data) {
                assert!((g - w).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0, 2.0, 3.0, -100.0];
        softmax(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0] && xs[0] > xs[3]);
    }

    #[test]
    fn softmax_extreme_stable() {
        let mut xs = vec![1000.0, 999.0];
        softmax(&mut xs);
        assert!(xs.iter().all(|x| x.is_finite()));
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn topk_order() {
        assert_eq!(top_k_indices(&[0.1, 0.9, 0.5, 0.9], 3), vec![1, 3, 2]);
    }

    #[test]
    fn silu_grad_numeric() {
        for &x in &[-3.0f32, -0.5, 0.0, 0.7, 2.5] {
            let eps = 1e-3;
            let num = (silu(x + eps) - silu(x - eps)) / (2.0 * eps);
            assert!((num - silu_grad(x)).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0, 4.0];
        let g = vec![1.0, 1.0];
        let mut out = vec![0.0; 2];
        rmsnorm(&x, &g, &mut out);
        let rms = ((9.0 + 16.0) / 2.0f32).sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-4);
        assert!((out[1] - 4.0 / rms).abs() < 1e-4);
    }

    #[test]
    fn dot_axpy_consistent() {
        prop::for_all(13, 30, |rng, _| {
            let n = 1 + rng.below(50);
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut y: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let y0 = y.clone();
            let alpha = rng.normal();
            axpy(alpha, &x, &mut y);
            for i in 0..n {
                assert!((y[i] - (y0[i] + alpha * x[i])).abs() < 1e-4);
            }
            let d = dot(&x, &y0);
            let naive: f32 = x.iter().zip(&y0).map(|(a, b)| a * b).sum();
            assert!((d - naive).abs() < 1e-3 * (1.0 + naive.abs()));
        });
    }
}
