//! A single SwiGLU expert: `y = (silu(x @ wg) * (x @ wu)) @ wd`.

use crate::tensor::{silu, Tensor2};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Expert {
    /// `[H, F]` gate projection.
    pub wg: Tensor2,
    /// `[H, F]` up projection.
    pub wu: Tensor2,
    /// `[F, H]` down projection.
    pub wd: Tensor2,
}

impl Expert {
    pub fn new(d_model: usize, d_ff: usize, rng: &mut Rng) -> Expert {
        let s1 = 1.0 / (d_model as f32).sqrt();
        let s2 = 1.0 / (d_ff as f32).sqrt();
        Expert {
            wg: Tensor2::randn(d_model, d_ff, rng, s1),
            wu: Tensor2::randn(d_model, d_ff, rng, s1),
            wd: Tensor2::randn(d_ff, d_model, rng, s2),
        }
    }

    /// Apply to a single token row; `out` is accumulated with weight `w`.
    pub fn ffn_row_acc(&self, x: &[f32], w: f32, out: &mut [f32]) {
        let f = self.wg.cols;
        let mut h = vec![0.0f32; f];
        // h = silu(x@wg) * (x@wu); column-wise dot against transposed view
        // would thrash cache, so go row-wise over x.
        for (k, &xk) in x.iter().enumerate() {
            if xk == 0.0 {
                continue;
            }
            let g = self.wg.row(k);
            for j in 0..f {
                h[j] += xk * g[j];
            }
        }
        let mut u = vec![0.0f32; f];
        for (k, &xk) in x.iter().enumerate() {
            if xk == 0.0 {
                continue;
            }
            let urow = self.wu.row(k);
            for j in 0..f {
                u[j] += xk * urow[j];
            }
        }
        for j in 0..f {
            h[j] = silu(h[j]) * u[j];
        }
        for (j, &hj) in h.iter().enumerate() {
            if hj != 0.0 {
                let d = self.wd.row(j);
                for (o, oo) in out.iter_mut().enumerate() {
                    *oo += w * hj * d[o];
                }
            }
        }
    }

    /// Batched forward: `x [T, H] -> y [T, H]`.
    pub fn ffn(&self, x: &Tensor2) -> Tensor2 {
        let g = x.matmul(&self.wg);
        let u = x.matmul(&self.wu);
        let mut h = Tensor2::zeros(x.rows, self.wg.cols);
        for i in 0..h.data.len() {
            h.data[i] = silu(g.data[i]) * u.data[i];
        }
        h.matmul(&self.wd)
    }

    pub fn n_params(&self) -> usize {
        self.wg.data.len() + self.wu.data.len() + self.wd.data.len()
    }

    /// Reconstruction distance to another expert (used in tests).
    pub fn weight_distance(&self, other: &Expert) -> f32 {
        let d = |a: &Tensor2, b: &Tensor2| -> f32 {
            a.data.iter().zip(&b.data).map(|(x, y)| (x - y) * (x - y)).sum()
        };
        (d(&self.wg, &other.wg) + d(&self.wu, &other.wu) + d(&self.wd, &other.wd)).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_and_batch_agree() {
        let mut rng = Rng::new(31);
        let e = Expert::new(32, 48, &mut rng);
        let x = Tensor2::randn(5, 32, &mut rng, 1.0);
        let batch = e.ffn(&x);
        for t in 0..5 {
            let mut row = vec![0.0f32; 32];
            e.ffn_row_acc(x.row(t), 1.0, &mut row);
            for (a, b) in row.iter().zip(batch.row(t)) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn weighted_accumulation() {
        let mut rng = Rng::new(32);
        let e = Expert::new(16, 24, &mut rng);
        let x: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let mut a = vec![0.0f32; 16];
        e.ffn_row_acc(&x, 0.25, &mut a);
        let mut b = vec![0.0f32; 16];
        e.ffn_row_acc(&x, 1.0, &mut b);
        for (ai, bi) in a.iter().zip(&b) {
            assert!((ai - 0.25 * bi).abs() < 1e-5);
        }
    }
}
