//! The full MoE decoder: embedding → N × (attention + MoE FFN) → head.
//!
//! `forward_opts` is the single full-sequence forward shared by training
//! -adjacent code paths (PPL eval, calibration, ε-table construction,
//! OTP distillation). Hooks:
//!
//! * [`ForwardOpts::stats`] — collect routing statistics (PMQ §3.2.2);
//! * [`ForwardOpts::provider`] — substitute expert execution (quantized
//!   experts, single-expert-quantized ε probes, PJRT execution);
//! * [`ForwardOpts::pruner`] — drop low-rank experts per token (OTP/ODP).

use anyhow::Result;

use crate::config::ModelConfig;
use crate::tensor::{rmsnorm, Tensor2};
use crate::util::rng::Rng;

use super::attention::{mat_vec, Attention};
use super::dispatch::{dispatch_moe_layer, DispatchHooks, ProviderExec};
use super::expert::Expert;
use super::gating::Route;
use super::stats::RoutingStats;

/// Identifies an expert within a layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ExpertId {
    Routed(usize),
    Shared(usize),
}

/// Pluggable expert execution (native f32, quantized, PJRT, ε-probe...).
///
/// The batch method is the primary interface — the expert-grouped
/// dispatcher (`moe::dispatch`) hands every provider one contiguous
/// token group per expert, so packed-weight implementations can decode
/// each tile once per group. The row method is the degenerate
/// single-row case. Each default is written in terms of the other:
/// **implement at least one** (row-only providers inherit a per-row
/// batch loop; batch-first providers inherit a 1-row wrapper).
///
/// `Sync` because independent expert groups execute on scoped threads.
pub trait ExpertProvider: Sync {
    /// Compute `out += w * F_e(x)` for expert `id` in `layer`.
    fn expert_ffn_acc(&self, layer: usize, id: ExpertId, x: &[f32], w: f32, out: &mut [f32]) {
        let xb = Tensor2::from_vec(1, x.len(), x.to_vec());
        let mut ob = Tensor2::zeros(1, out.len());
        self.expert_ffn_batch_acc(layer, id, &xb, &[w], &mut ob);
        for (o, v) in out.iter_mut().zip(&ob.data) {
            *o += v;
        }
    }

    /// Batch path: `out.row(i) += weights[i] * F_e(x.row(i))` over a
    /// gathered token group `x [G, H]`.
    fn expert_ffn_batch_acc(
        &self,
        layer: usize,
        id: ExpertId,
        x: &Tensor2,
        weights: &[f32],
        out: &mut Tensor2,
    ) {
        for i in 0..x.rows {
            self.expert_ffn_acc(layer, id, x.row(i), weights[i], out.row_mut(i));
        }
    }

    /// Pre-execute residency hook: the dispatcher announces one layer's
    /// routed expert set after routing and before any expert executes.
    /// Providers whose weights page in from storage (`QuantModel` over a
    /// `PagedStore`) batch their I/O here, outside the parallel execute
    /// region; fully resident providers keep the no-op default.
    fn ensure_resident(&self, _layer: usize, _experts: &[usize]) -> Result<()> {
        Ok(())
    }
}

/// Token-wise dynamic expert pruning (OTP learnable router, ODP rule,
/// random baseline). Returns how many of the rank-sorted top-k experts to
/// KEEP (1..=k). `Send` so an engine carrying a boxed pruner can live on
/// the server's dedicated engine thread.
pub trait Pruner: Send {
    fn keep(&mut self, layer: usize, x: &[f32], route: &Route) -> usize;
}

#[derive(Default)]
pub struct ForwardOpts<'a> {
    pub stats: Option<&'a mut RoutingStats>,
    pub provider: Option<&'a dyn ExpertProvider>,
    pub pruner: Option<&'a mut dyn Pruner>,
    /// Accumulates (kept, k) pairs per token-layer for pruning-ratio
    /// accounting (Table 6).
    pub pruning_counter: Option<&'a mut (u64, u64)>,
    /// Capture per-layer MoE inputs (post-norm token rows) for PMQ
    /// calibration: `capture[layer].push(x)`. Must be pre-sized to
    /// `n_layers` empty vecs.
    pub capture_moe_inputs: Option<&'a mut Vec<Vec<Vec<f32>>>>,
}

pub struct Block {
    pub attn_norm: Vec<f32>,
    pub attn: Attention,
    pub moe_norm: Vec<f32>,
    pub gate: Tensor2,
    pub experts: Vec<Expert>,
    pub shared: Vec<Expert>,
}

pub struct MoeModel {
    pub cfg: ModelConfig,
    pub embed: Tensor2,
    pub blocks: Vec<Block>,
    pub final_norm: Vec<f32>,
    pub lm_head: Tensor2,
}

impl MoeModel {
    /// Random init from a seed (deterministic).
    pub fn new(cfg: &ModelConfig, seed: u64) -> MoeModel {
        let mut rng = Rng::new(seed);
        let h = cfg.d_model;
        let blocks = (0..cfg.n_layers)
            .map(|_| Block {
                attn_norm: vec![1.0; h],
                attn: Attention::new(h, cfg.n_heads, cfg.rope_theta, &mut rng),
                moe_norm: vec![1.0; h],
                gate: Tensor2::randn(h, cfg.n_experts, &mut rng, 1.0 / (h as f32).sqrt()),
                experts: (0..cfg.n_experts).map(|_| Expert::new(h, cfg.d_ff, &mut rng)).collect(),
                shared: (0..cfg.n_shared_experts)
                    .map(|_| Expert::new(h, cfg.d_ff, &mut rng))
                    .collect(),
            })
            .collect();
        MoeModel {
            cfg: cfg.clone(),
            embed: Tensor2::randn(cfg.vocab_size, h, &mut rng, 0.02),
            blocks,
            final_norm: vec![1.0; h],
            lm_head: Tensor2::randn(h, cfg.vocab_size, &mut rng, 1.0 / (h as f32).sqrt()),
        }
    }

    /// Full-sequence forward → logits `[T, V]`.
    pub fn forward(&self, tokens: &[u16]) -> Tensor2 {
        self.forward_opts(tokens, &mut ForwardOpts::default())
    }

    pub fn forward_opts(&self, tokens: &[u16], opts: &mut ForwardOpts) -> Tensor2 {
        let h = self.cfg.d_model;
        let t = tokens.len();
        let mut x = Tensor2::zeros(t, h);
        for (i, &tok) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(self.embed.row(tok as usize));
        }
        let mut normed = Tensor2::zeros(t, h);
        for (l, block) in self.blocks.iter().enumerate() {
            // attention sub-layer
            for i in 0..t {
                rmsnorm(x.row(i), &block.attn_norm, normed.row_mut(i));
            }
            let attn_out = block.attn.forward(&normed, 0);
            x.add_assign(&attn_out);
            // MoE sub-layer: expert-grouped dispatch shared with the
            // decode engine — each expert runs once per token group, so
            // quantized providers decode packed tiles once per group
            let exec = ProviderExec(opts.provider.unwrap_or(self as &dyn ExpertProvider));
            let mut hooks = DispatchHooks {
                stats: opts.stats.as_deref_mut(),
                pruner: opts.pruner.as_deref_mut(),
                pruning_counter: opts.pruning_counter.as_deref_mut(),
                capture_moe_inputs: opts.capture_moe_inputs.as_deref_mut(),
            };
            for i in 0..t {
                rmsnorm(x.row(i), &block.moe_norm, normed.row_mut(i));
            }
            // in-memory providers cannot fail; a paged provider's
            // residency I/O error is fatal to a non-Result forward
            dispatch_moe_layer(
                l,
                &block.gate,
                self.cfg.top_k,
                block.shared.len(),
                &normed,
                &exec,
                &mut hooks,
                &mut x,
            )
            .expect("expert dispatch failed (paging I/O?)");
        }
        let mut logits = Tensor2::zeros(t, self.cfg.vocab_size);
        for i in 0..t {
            rmsnorm(x.row(i), &self.final_norm, normed.row_mut(i));
            let row = mat_vec(&self.lm_head, normed.row(i));
            logits.row_mut(i).copy_from_slice(&row);
        }
        logits
    }

    /// Mean cross-entropy (nats/token) of next-token prediction.
    pub fn nll(&self, tokens: &[u16], opts: &mut ForwardOpts) -> f64 {
        let logits = self.forward_opts(tokens, opts);
        nll_from_logits(&logits, tokens)
    }

    /// Perplexity over a set of sequences.
    pub fn perplexity(&self, seqs: &[Vec<u16>], opts: &mut ForwardOpts) -> f64 {
        let mut total = 0.0;
        let mut count = 0usize;
        for s in seqs {
            total += self.nll(s, opts) * (s.len() - 1) as f64;
            count += s.len() - 1;
        }
        (total / count.max(1) as f64).exp()
    }

    pub fn n_params(&self) -> usize {
        let mut n = self.embed.data.len() + self.lm_head.data.len() + self.final_norm.len();
        for b in &self.blocks {
            n += b.attn.n_params() + b.attn_norm.len() + b.moe_norm.len() + b.gate.data.len();
            n += b.experts.iter().map(|e| e.n_params()).sum::<usize>();
            n += b.shared.iter().map(|e| e.n_params()).sum::<usize>();
        }
        n
    }

    /// f16-equivalent parameter bytes (the paper reports 16-bit params).
    pub fn nbytes_fp16(&self) -> u64 {
        (self.n_params() * 2) as u64
    }

    pub fn load(path: &str) -> Result<MoeModel> {
        super::checkpoint::load(path)
    }

    pub fn save(&self, path: &str) -> Result<()> {
        super::checkpoint::save(self, path)
    }
}

/// The model is its own fp expert provider — the `opts.provider == None`
/// case of `forward_opts` is just dispatch over these weights.
impl ExpertProvider for MoeModel {
    fn expert_ffn_acc(&self, layer: usize, id: ExpertId, x: &[f32], w: f32, out: &mut [f32]) {
        let b = &self.blocks[layer];
        match id {
            ExpertId::Routed(e) => b.experts[e].ffn_row_acc(x, w, out),
            ExpertId::Shared(s) => b.shared[s].ffn_row_acc(x, w, out),
        }
    }
}

/// Mean next-token cross-entropy from `[T, V]` logits.
pub fn nll_from_logits(logits: &Tensor2, tokens: &[u16]) -> f64 {
    let t = tokens.len();
    let mut total = 0.0f64;
    for i in 0..t - 1 {
        let row = logits.row(i);
        let target = tokens[i + 1] as usize;
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = m + row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln();
        total += (lse - row[target]) as f64;
    }
    total / (t - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "test".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 48,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 1,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        }
    }

    #[test]
    fn forward_shapes_and_finite() {
        let m = MoeModel::new(&tiny_cfg(), 1);
        let logits = m.forward(&[1, 17, 20, 33, 5]);
        assert_eq!((logits.rows, logits.cols), (5, 64));
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn stats_collected() {
        let m = MoeModel::new(&tiny_cfg(), 1);
        let mut stats = RoutingStats::new(2, 4);
        let mut opts = ForwardOpts { stats: Some(&mut stats), ..Default::default() };
        m.forward_opts(&[1, 17, 20, 33, 5, 40, 41, 42], &mut opts);
        assert_eq!(stats.tokens, 8);
        // every token activates exactly top_k experts per layer
        let layer0: u64 = (0..4).map(|e| stats.counts[e]).sum();
        assert_eq!(layer0, 8 * 2);
    }

    #[test]
    fn pruner_reduces_activation() {
        struct KeepOne;
        impl Pruner for KeepOne {
            fn keep(&mut self, _l: usize, _x: &[f32], _r: &Route) -> usize {
                1
            }
        }
        let m = MoeModel::new(&tiny_cfg(), 1);
        let mut counter = (0u64, 0u64);
        let mut p = KeepOne;
        let mut opts = ForwardOpts {
            pruner: Some(&mut p),
            pruning_counter: Some(&mut counter),
            ..Default::default()
        };
        let out = m.forward_opts(&[1, 17, 20, 33], &mut opts);
        assert!(out.data.iter().all(|x| x.is_finite()));
        assert_eq!(counter.0, 4 * 2); // kept 1 of 2 per token-layer
        assert_eq!(counter.1, 4 * 2 * 2);
    }

    #[test]
    fn provider_substitution_changes_nothing_when_identical() {
        struct Mirror<'a>(&'a MoeModel);
        impl ExpertProvider for Mirror<'_> {
            fn expert_ffn_acc(&self, layer: usize, id: ExpertId, x: &[f32], w: f32, out: &mut [f32]) {
                let b = &self.0.blocks[layer];
                match id {
                    ExpertId::Routed(e) => b.experts[e].ffn_row_acc(x, w, out),
                    ExpertId::Shared(s) => b.shared[s].ffn_row_acc(x, w, out),
                }
            }
        }
        let m = MoeModel::new(&tiny_cfg(), 2);
        let toks = [1u16, 17, 20, 33, 60];
        let base = m.forward(&toks);
        let mirror = Mirror(&m);
        let mut opts = ForwardOpts { provider: Some(&mirror), ..Default::default() };
        let got = m.forward_opts(&toks, &mut opts);
        for (a, b) in got.data.iter().zip(&base.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn nll_of_uniform_logits_is_log_v() {
        let logits = Tensor2::zeros(3, 64);
        let nll = nll_from_logits(&logits, &[1, 2, 3]);
        assert!((nll - (64f64).ln()).abs() < 1e-5);
    }
}
