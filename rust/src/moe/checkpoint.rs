//! Checkpoint format: a JSON header (config + tensor directory) followed
//! by raw little-endian f32 payloads, so checkpoints stream without an
//! allocation-heavy parse. Written by the trainer, read by every example
//! and bench.

use std::io::{BufReader, BufWriter, Read, Write};

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::tensor::Tensor2;
use crate::util::json::{self, Value};

use super::attention::Attention;
use super::expert::Expert;
use super::model::{Block, MoeModel};

const MAGIC: &[u8; 8] = b"MCSHARP1";

fn write_tensor(w: &mut impl Write, t: &Tensor2) -> Result<()> {
    for &v in &t.data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn write_vec(w: &mut impl Write, v: &[f32]) -> Result<()> {
    for &x in v {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_tensor(r: &mut impl Read, rows: usize, cols: usize) -> Result<Tensor2> {
    Ok(Tensor2::from_vec(rows, cols, read_f32s(r, rows * cols)?))
}

fn config_json(c: &ModelConfig) -> Value {
    json::obj(vec![
        ("name", json::s(&c.name)),
        ("family", json::s(&c.family)),
        ("vocab_size", json::num(c.vocab_size as f64)),
        ("d_model", json::num(c.d_model as f64)),
        ("n_layers", json::num(c.n_layers as f64)),
        ("n_heads", json::num(c.n_heads as f64)),
        ("d_ff", json::num(c.d_ff as f64)),
        ("n_experts", json::num(c.n_experts as f64)),
        ("top_k", json::num(c.top_k as f64)),
        ("n_shared_experts", json::num(c.n_shared_experts as f64)),
        ("max_seq_len", json::num(c.max_seq_len as f64)),
        ("rope_theta", json::num(c.rope_theta as f64)),
        ("modalities", json::num(c.modalities as f64)),
        (
            "buckets",
            Value::Arr(c.buckets.iter().map(|&b| json::num(b as f64)).collect()),
        ),
    ])
}

pub fn save(model: &MoeModel, path: &str) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    let header = config_json(&model.cfg).to_json();
    w.write_all(&(header.len() as u64).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    write_tensor(&mut w, &model.embed)?;
    for b in &model.blocks {
        write_vec(&mut w, &b.attn_norm)?;
        write_tensor(&mut w, &b.attn.wq)?;
        write_tensor(&mut w, &b.attn.wk)?;
        write_tensor(&mut w, &b.attn.wv)?;
        write_tensor(&mut w, &b.attn.wo)?;
        write_vec(&mut w, &b.moe_norm)?;
        write_tensor(&mut w, &b.gate)?;
        for e in b.experts.iter().chain(&b.shared) {
            write_tensor(&mut w, &e.wg)?;
            write_tensor(&mut w, &e.wu)?;
            write_tensor(&mut w, &e.wd)?;
        }
    }
    write_vec(&mut w, &model.final_norm)?;
    write_tensor(&mut w, &model.lm_head)?;
    w.flush()?;
    Ok(())
}

pub fn load(path: &str) -> Result<MoeModel> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path}: not an MC# checkpoint");
    }
    let mut len = [0u8; 8];
    r.read_exact(&mut len)?;
    let mut header = vec![0u8; u64::from_le_bytes(len) as usize];
    r.read_exact(&mut header)?;
    let cfg = ModelConfig::from_json(&Value::parse(std::str::from_utf8(&header)?)?)?;
    let h = cfg.d_model;
    let embed = read_tensor(&mut r, cfg.vocab_size, h)?;
    let mut blocks = Vec::new();
    for _ in 0..cfg.n_layers {
        let attn_norm = read_f32s(&mut r, h)?;
        let wq = read_tensor(&mut r, h, h)?;
        let wk = read_tensor(&mut r, h, h)?;
        let wv = read_tensor(&mut r, h, h)?;
        let wo = read_tensor(&mut r, h, h)?;
        let moe_norm = read_f32s(&mut r, h)?;
        let gate = read_tensor(&mut r, h, cfg.n_experts)?;
        let read_expert = |r: &mut BufReader<std::fs::File>| -> Result<Expert> {
            Ok(Expert {
                wg: read_tensor(r, h, cfg.d_ff)?,
                wu: read_tensor(r, h, cfg.d_ff)?,
                wd: read_tensor(r, cfg.d_ff, h)?,
            })
        };
        let experts: Vec<Expert> = (0..cfg.n_experts)
            .map(|_| read_expert(&mut r))
            .collect::<Result<_>>()?;
        let shared: Vec<Expert> = (0..cfg.n_shared_experts)
            .map(|_| read_expert(&mut r))
            .collect::<Result<_>>()?;
        blocks.push(Block {
            attn_norm,
            attn: Attention::from_parts(wq, wk, wv, wo, cfg.n_heads, cfg.rope_theta),
            moe_norm,
            gate,
            experts,
            shared,
        });
    }
    let final_norm = read_f32s(&mut r, h)?;
    let lm_head = read_tensor(&mut r, h, cfg.vocab_size)?;
    Ok(MoeModel { cfg, embed, blocks, final_norm, lm_head })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let cfg = ModelConfig {
            name: "ckpt-test".into(),
            family: "mixtral".into(),
            vocab_size: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            n_experts: 3,
            top_k: 2,
            n_shared_experts: 1,
            max_seq_len: 32,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4, 16],
        };
        let m = MoeModel::new(&cfg, 99);
        let path = std::env::temp_dir().join("mcsharp_ckpt_test.bin");
        let path = path.to_str().unwrap();
        save(&m, path).unwrap();
        let m2 = load(path).unwrap();
        assert_eq!(m2.cfg, cfg);
        let toks = [1u16, 5, 9, 30];
        let a = m.forward(&toks);
        let b = m2.forward(&toks);
        assert_eq!(a.data, b.data);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = std::env::temp_dir().join("mcsharp_badmagic.bin");
        std::fs::write(&path, b"NOTMAGIC........").unwrap();
        assert!(load(path.to_str().unwrap()).is_err());
        std::fs::remove_file(path).ok();
    }
}
