//! Routing statistics collected during calibration forward passes —
//! the raw material for PMQ's significance factors (paper §3.2.2):
//! activation frequency `φ_i = n_i / N` and mean routing weight
//! `w_i = Σ σ_j / N` per (layer, expert), exactly the quantities the
//! Fig. 4/5 heatmaps plot.

#[derive(Clone, Debug)]
pub struct RoutingStats {
    pub n_layers: usize,
    pub n_experts: usize,
    /// Activation counts per (layer, expert).
    pub counts: Vec<u64>,
    /// Sum of routing weights per (layer, expert) over *all* tokens.
    pub weight_sums: Vec<f64>,
    /// Total routed tokens (per layer each token routes once).
    pub tokens: u64,
}

impl RoutingStats {
    pub fn new(n_layers: usize, n_experts: usize) -> RoutingStats {
        RoutingStats {
            n_layers,
            n_experts,
            counts: vec![0; n_layers * n_experts],
            weight_sums: vec![0.0; n_layers * n_experts],
            tokens: 0,
        }
    }

    #[inline]
    pub fn record(&mut self, layer: usize, expert: usize, weight: f32) {
        let i = layer * self.n_experts + expert;
        self.counts[i] += 1;
        self.weight_sums[i] += weight as f64;
    }

    /// Called once per token (after all layers recorded). We count tokens
    /// layer-independently, so record layer 0's visit.
    #[inline]
    pub fn bump_tokens(&mut self) {
        self.tokens += 1;
    }

    /// Activation frequency φ for (layer, expert).
    pub fn frequency(&self, layer: usize, expert: usize) -> f64 {
        if self.tokens == 0 {
            return 0.0;
        }
        self.counts[layer * self.n_experts + expert] as f64 / self.tokens as f64
    }

    /// Mean routing weight w for (layer, expert) (averaged over all
    /// tokens, activated or not — matching the paper's Σσ/N).
    pub fn mean_weight(&self, layer: usize, expert: usize) -> f64 {
        if self.tokens == 0 {
            return 0.0;
        }
        self.weight_sums[layer * self.n_experts + expert] / self.tokens as f64
    }

    /// Gini-style imbalance of activation counts in one layer — used to
    /// quantify the LLM-vs-VLM imbalance claim (Fig. 5).
    pub fn layer_imbalance(&self, layer: usize) -> f64 {
        let row: Vec<f64> = (0..self.n_experts)
            .map(|e| self.counts[layer * self.n_experts + e] as f64)
            .collect();
        gini(&row)
    }

    pub fn mean_imbalance(&self) -> f64 {
        (0..self.n_layers).map(|l| self.layer_imbalance(l)).sum::<f64>() / self.n_layers as f64
    }
}

/// Gini coefficient of a non-negative vector (0 = perfectly even).
pub fn gini(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n == 0 {
        return 0.0;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let sum: f64 = sorted.iter().sum();
    if sum == 0.0 {
        return 0.0;
    }
    let mut cum = 0.0;
    let mut b = 0.0;
    for &x in &sorted {
        cum += x;
        b += cum;
    }
    (n as f64 + 1.0 - 2.0 * b / sum) / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequency_and_weight() {
        let mut s = RoutingStats::new(2, 4);
        for _ in 0..10 {
            s.bump_tokens();
            s.record(0, 1, 0.6);
            s.record(0, 2, 0.4);
            s.record(1, 0, 1.0);
        }
        assert!((s.frequency(0, 1) - 1.0).abs() < 1e-9);
        assert!((s.frequency(0, 3) - 0.0).abs() < 1e-9);
        // f32 weights accumulate into f64 sums: allow f32 rounding
        assert!((s.mean_weight(0, 2) - 0.4).abs() < 1e-6);
        assert!((s.mean_weight(1, 0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gini_bounds() {
        assert!(gini(&[1.0, 1.0, 1.0, 1.0]) < 1e-9);
        assert!(gini(&[0.0, 0.0, 0.0, 10.0]) > 0.7);
        assert_eq!(gini(&[]), 0.0);
        assert_eq!(gini(&[0.0, 0.0]), 0.0);
    }
}
