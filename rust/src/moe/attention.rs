//! Multi-head causal self-attention with RoPE.
//!
//! Three paths share the same weights:
//! * [`Attention::forward`] — full-sequence (training / PPL / calibration);
//! * [`Attention::forward_step`] — single-position decode against the
//!   paged [`KvPool`] (the serving decode hot path);
//! * [`Attention::forward_chunk`] — C positions at once against the
//!   pool (chunked prefill: projections ride the blocked `matmul`, and
//!   per row it is bit-identical to `forward_step` — both accumulate
//!   over k ascending with the same zero-skip `axpy`).
//!
//! The per-pair RoPE inverse frequencies are precomputed once per
//! [`Attention`] ([`Attention::from_parts`]) instead of calling `powf`
//! per position × head × pair; the free [`rope`] keeps the direct
//! computation as the reference (and for the training backward path).
//!
//! Property tests assert step == full-sequence and chunk == step.

use crate::moe::kv::{KvPool, LayerKv};
use crate::tensor::{softmax, Tensor2};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Attention {
    pub wq: Tensor2,
    pub wk: Tensor2,
    pub wv: Tensor2,
    pub wo: Tensor2,
    pub n_heads: usize,
    pub rope_theta: f32,
    /// Per-pair RoPE inverse frequencies (d_head/2 entries), computed
    /// once at construction.
    inv_freq: Vec<f32>,
}

/// The table [`Attention`] precomputes: `1/theta^(2p/d_head)` for pair
/// `p` — exactly the value [`rope`] derives per call.
pub fn inv_freq_table(d_head: usize, theta: f32) -> Vec<f32> {
    let mut f = Vec::with_capacity(d_head / 2);
    let mut i = 0;
    while i + 1 < d_head {
        f.push(1.0 / theta.powf(i as f32 / d_head as f32));
        i += 2;
    }
    f
}

/// Apply RoPE in place to one `[H]` row at position `pos` using a
/// precomputed inverse-frequency table.
pub fn rope_with(x: &mut [f32], pos: usize, n_heads: usize, inv_freq: &[f32]) {
    let d_head = x.len() / n_heads;
    for h in 0..n_heads {
        let base = h * d_head;
        for (p, &freq) in inv_freq.iter().enumerate() {
            let i = 2 * p;
            let angle = pos as f32 * freq;
            let (sin, cos) = angle.sin_cos();
            let (a, b) = (x[base + i], x[base + i + 1]);
            x[base + i] = a * cos - b * sin;
            x[base + i + 1] = a * sin + b * cos;
        }
    }
}

/// Apply RoPE in place to one `[H]` row at position `pos` (per head),
/// recomputing frequencies — the reference path (training backward).
pub fn rope(x: &mut [f32], pos: usize, n_heads: usize, theta: f32) {
    let d_head = x.len() / n_heads;
    for h in 0..n_heads {
        let base = h * d_head;
        let mut i = 0;
        while i + 1 < d_head {
            let freq = 1.0 / theta.powf(i as f32 / d_head as f32);
            let angle = pos as f32 * freq;
            let (sin, cos) = angle.sin_cos();
            let (a, b) = (x[base + i], x[base + i + 1]);
            x[base + i] = a * cos - b * sin;
            x[base + i + 1] = a * sin + b * cos;
            i += 2;
        }
    }
}

impl Attention {
    pub fn new(d_model: usize, n_heads: usize, rope_theta: f32, rng: &mut Rng) -> Attention {
        let s = 1.0 / (d_model as f32).sqrt();
        Attention::from_parts(
            Tensor2::randn(d_model, d_model, rng, s),
            Tensor2::randn(d_model, d_model, rng, s),
            Tensor2::randn(d_model, d_model, rng, s),
            Tensor2::randn(d_model, d_model, rng, s),
            n_heads,
            rope_theta,
        )
    }

    /// Build from loaded weights (checkpoint paths), deriving the RoPE
    /// table from the head geometry.
    pub fn from_parts(
        wq: Tensor2,
        wk: Tensor2,
        wv: Tensor2,
        wo: Tensor2,
        n_heads: usize,
        rope_theta: f32,
    ) -> Attention {
        let d_head = wq.cols / n_heads;
        let inv_freq = inv_freq_table(d_head, rope_theta);
        Attention { wq, wk, wv, wo, n_heads, rope_theta, inv_freq }
    }

    #[inline]
    fn rope_row(&self, x: &mut [f32], pos: usize) {
        rope_with(x, pos, self.n_heads, &self.inv_freq);
    }

    /// Full-sequence causal attention over `x [T, H]` starting at absolute
    /// position `pos0` (0 for training).
    pub fn forward(&self, x: &Tensor2, pos0: usize) -> Tensor2 {
        let (t, h) = (x.rows, x.cols);
        let d_head = h / self.n_heads;
        let scale = 1.0 / (d_head as f32).sqrt();
        let mut q = x.matmul(&self.wq);
        let mut k = x.matmul(&self.wk);
        let v = x.matmul(&self.wv);
        for i in 0..t {
            self.rope_row(q.row_mut(i), pos0 + i);
            self.rope_row(k.row_mut(i), pos0 + i);
        }
        let mut ctx = Tensor2::zeros(t, h);
        let mut scores = vec![0.0f32; t];
        for head in 0..self.n_heads {
            let base = head * d_head;
            for i in 0..t {
                let qi = &q.row(i)[base..base + d_head];
                for (j, s) in scores.iter_mut().enumerate().take(i + 1) {
                    let kj = &k.row(j)[base..base + d_head];
                    *s = qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
                softmax(&mut scores[..i + 1]);
                let orow = ctx.row_mut(i);
                for j in 0..=i {
                    let w = scores[j];
                    let vj = &v.row(j)[base..base + d_head];
                    for (d, &vv) in vj.iter().enumerate() {
                        orow[base + d] += w * vv;
                    }
                }
            }
        }
        ctx.matmul(&self.wo)
    }

    /// Attend `q` (already RoPE'd) at absolute position `pos` over the
    /// first `pos + 1` cached positions, accumulating into `ctx`. Walks
    /// KV pages once for scores and once for the weighted sum; the
    /// per-element accumulation order matches `forward` exactly.
    fn attend(&self, q: &[f32], pos: usize, pool: &KvPool, lk: &LayerKv, ctx: &mut [f32]) {
        let h = q.len();
        let d_head = h / self.n_heads;
        let scale = 1.0 / (d_head as f32).sqrt();
        let t = pos + 1;
        let mut scores = vec![0.0f32; self.n_heads * t];
        pool.walk(lk, t, |j, krow, _| {
            for head in 0..self.n_heads {
                let base = head * d_head;
                let qh = &q[base..base + d_head];
                let kj = &krow[base..base + d_head];
                scores[head * t + j] = qh.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
        });
        for head in 0..self.n_heads {
            softmax(&mut scores[head * t..(head + 1) * t]);
        }
        pool.walk(lk, t, |j, _, vrow| {
            for head in 0..self.n_heads {
                let base = head * d_head;
                let w = scores[head * t + j];
                for (d, &vv) in vrow[base..base + d_head].iter().enumerate() {
                    ctx[base + d] += w * vv;
                }
            }
        });
    }

    /// Single-token decode: append this position's K/V to the
    /// sequence's page table, attend over the whole cache. `x` is the
    /// `[H]` input row at absolute position `lk.len()`.
    pub fn forward_step(&self, x: &[f32], pool: &mut KvPool, lk: &mut LayerKv) -> Vec<f32> {
        let pos = lk.len();
        let mut q = mat_vec(&self.wq, x);
        let mut k = mat_vec(&self.wk, x);
        let v = mat_vec(&self.wv, x);
        self.rope_row(&mut q, pos);
        self.rope_row(&mut k, pos);
        pool.append(lk, &k, &v);
        let mut ctx = vec![0.0f32; x.len()];
        self.attend(&q, pos, pool, lk, &mut ctx);
        mat_vec(&self.wo, &ctx)
    }

    /// Chunked prefill: process `x [C, H]` — the next C positions of
    /// one sequence — in a single call. Q/K/V ride the blocked matmul
    /// (bit-identical per row to `mat_vec`: same ascending-k,
    /// zero-skipping `axpy` chain), all C K/V rows are appended, then
    /// each row attends causally over its own prefix. With C == 1 this
    /// is exactly `forward_step`.
    pub fn forward_chunk(&self, x: &Tensor2, pool: &mut KvPool, lk: &mut LayerKv) -> Tensor2 {
        let (c, h) = (x.rows, x.cols);
        let pos0 = lk.len();
        let mut q = x.matmul(&self.wq);
        let mut k = x.matmul(&self.wk);
        let v = x.matmul(&self.wv);
        for i in 0..c {
            self.rope_row(q.row_mut(i), pos0 + i);
            self.rope_row(k.row_mut(i), pos0 + i);
        }
        for i in 0..c {
            pool.append(lk, k.row(i), v.row(i));
        }
        let mut ctx = Tensor2::zeros(c, h);
        for i in 0..c {
            self.attend(q.row(i), pos0 + i, pool, lk, ctx.row_mut(i));
        }
        ctx.matmul(&self.wo)
    }

    pub fn n_params(&self) -> usize {
        4 * self.wq.data.len()
    }
}

/// `w^T`-free row-major mat-vec: `y[j] = Σ_k x[k] * w[k, j]`.
pub fn mat_vec(w: &Tensor2, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; w.cols];
    for (k, &xk) in x.iter().enumerate() {
        if xk == 0.0 {
            continue;
        }
        crate::tensor::axpy(xk, w.row(k), &mut y);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::kv::SeqKv;
    use crate::util::prop;

    #[test]
    fn step_matches_full_sequence() {
        prop::for_all(51, 10, |rng, _| {
            let (h, heads, t) = (32, 4, 1 + rng.below(12));
            let attn = Attention::new(h, heads, 10_000.0, rng);
            let x = Tensor2::randn(t, h, rng, 1.0);
            let full = attn.forward(&x, 0);
            // page size 4: positions cross page boundaries
            let mut pool = KvPool::new(4, h, 1);
            let mut kv = SeqKv::new(1);
            for i in 0..t {
                let step = attn.forward_step(x.row(i), &mut pool, &mut kv.layers[0]);
                for (a, b) in step.iter().zip(full.row(i)) {
                    assert!((a - b).abs() < 1e-4, "pos {i}: {a} vs {b}");
                }
            }
            assert_eq!(kv.layers[0].len(), t);
        });
    }

    #[test]
    fn chunk_is_bit_identical_to_steps() {
        prop::for_all(52, 10, |rng, _| {
            let (h, heads, t) = (32, 4, 1 + rng.below(12));
            let attn = Attention::new(h, heads, 10_000.0, rng);
            let x = Tensor2::randn(t, h, rng, 1.0);
            let mut pool_a = KvPool::new(4, h, 1);
            let mut a = SeqKv::new(1);
            let chunk = attn.forward_chunk(&x, &mut pool_a, &mut a.layers[0]);
            let mut pool_b = KvPool::new(4, h, 1);
            let mut b = SeqKv::new(1);
            for i in 0..t {
                let step = attn.forward_step(x.row(i), &mut pool_b, &mut b.layers[0]);
                assert_eq!(chunk.row(i), &step[..], "pos {i} not bit-identical");
            }
        });
    }

    #[test]
    fn chunk_resumes_mid_sequence() {
        // prefill the first rows chunked, the rest stepped: the cache
        // contents must line up position for position
        let mut rng = Rng::new(53);
        let (h, heads, t, split) = (32, 4, 9, 5);
        let attn = Attention::new(h, heads, 10_000.0, &mut rng);
        let x = Tensor2::randn(t, h, &mut rng, 1.0);
        let full = attn.forward(&x, 0);
        let mut pool = KvPool::new(4, h, 1);
        let mut kv = SeqKv::new(1);
        let head = Tensor2::from_vec(split, h, x.data[..split * h].to_vec());
        let out = attn.forward_chunk(&head, &mut pool, &mut kv.layers[0]);
        for i in 0..split {
            for (a, b) in out.row(i).iter().zip(full.row(i)) {
                assert!((a - b).abs() < 1e-4);
            }
        }
        for i in split..t {
            let step = attn.forward_step(x.row(i), &mut pool, &mut kv.layers[0]);
            for (a, b) in step.iter().zip(full.row(i)) {
                assert!((a - b).abs() < 1e-4, "pos {i}: {a} vs {b}");
            }
        }
        assert_eq!(kv.layers[0].len(), t);
    }

    #[test]
    fn rope_with_table_matches_reference() {
        let mut rng = Rng::new(54);
        let (h, heads) = (32, 4);
        let table = inv_freq_table(h / heads, 10_000.0);
        for pos in [0usize, 1, 17, 255] {
            let x0: Vec<f32> = (0..h).map(|_| rng.normal()).collect();
            let mut a = x0.clone();
            let mut b = x0;
            rope(&mut a, pos, heads, 10_000.0);
            rope_with(&mut b, pos, heads, &table);
            assert_eq!(a, b, "table diverges from direct computation at pos {pos}");
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::new(5);
        let mut x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        rope(&mut x, 17, 4, 10_000.0);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-3);
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let mut rng = Rng::new(6);
        let x0: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let mut x = x0.clone();
        rope(&mut x, 0, 2, 10_000.0);
        for (a, b) in x.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn causal_prefix_invariance() {
        // output at position i must not depend on tokens after i
        let mut rng = Rng::new(7);
        let attn = Attention::new(16, 2, 10_000.0, &mut rng);
        let x = Tensor2::randn(6, 16, &mut rng, 1.0);
        let full = attn.forward(&x, 0);
        let prefix = Tensor2::from_vec(3, 16, x.data[..3 * 16].to_vec());
        let part = attn.forward(&prefix, 0);
        for i in 0..3 {
            for (a, b) in part.row(i).iter().zip(full.row(i)) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }
}
