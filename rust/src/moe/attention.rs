//! Multi-head causal self-attention with RoPE.
//!
//! Two paths share the same weights:
//! * [`Attention::forward`] — full-sequence (training / PPL / calibration);
//! * [`Attention::forward_step`] — single-position decode against a
//!   [`KvCache`] (the serving hot path).
//!
//! A property test asserts the two are numerically identical.

use crate::tensor::{softmax, Tensor2};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Attention {
    pub wq: Tensor2,
    pub wk: Tensor2,
    pub wv: Tensor2,
    pub wo: Tensor2,
    pub n_heads: usize,
    pub rope_theta: f32,
}

/// Per-sequence KV cache: K and V rows appended per decoded position.
#[derive(Clone, Debug, Default)]
pub struct KvCache {
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl KvCache {
    pub fn len(&self) -> usize {
        self.k.len()
    }

    pub fn is_empty(&self) -> bool {
        self.k.is_empty()
    }

    pub fn nbytes(&self) -> u64 {
        self.k
            .iter()
            .chain(self.v.iter())
            .map(|r| (r.len() * 4) as u64)
            .sum()
    }
}

/// Apply RoPE in place to one `[H]` row at position `pos` (per head).
pub fn rope(x: &mut [f32], pos: usize, n_heads: usize, theta: f32) {
    let d_head = x.len() / n_heads;
    for h in 0..n_heads {
        let base = h * d_head;
        let mut i = 0;
        while i + 1 < d_head {
            let freq = 1.0 / theta.powf(i as f32 / d_head as f32);
            let angle = pos as f32 * freq;
            let (sin, cos) = angle.sin_cos();
            let (a, b) = (x[base + i], x[base + i + 1]);
            x[base + i] = a * cos - b * sin;
            x[base + i + 1] = a * sin + b * cos;
            i += 2;
        }
    }
}

impl Attention {
    pub fn new(d_model: usize, n_heads: usize, rope_theta: f32, rng: &mut Rng) -> Attention {
        let s = 1.0 / (d_model as f32).sqrt();
        Attention {
            wq: Tensor2::randn(d_model, d_model, rng, s),
            wk: Tensor2::randn(d_model, d_model, rng, s),
            wv: Tensor2::randn(d_model, d_model, rng, s),
            wo: Tensor2::randn(d_model, d_model, rng, s),
            n_heads,
            rope_theta,
        }
    }

    /// Full-sequence causal attention over `x [T, H]` starting at absolute
    /// position `pos0` (0 for training).
    pub fn forward(&self, x: &Tensor2, pos0: usize) -> Tensor2 {
        let (t, h) = (x.rows, x.cols);
        let d_head = h / self.n_heads;
        let scale = 1.0 / (d_head as f32).sqrt();
        let mut q = x.matmul(&self.wq);
        let mut k = x.matmul(&self.wk);
        let v = x.matmul(&self.wv);
        for i in 0..t {
            rope(q.row_mut(i), pos0 + i, self.n_heads, self.rope_theta);
            rope(k.row_mut(i), pos0 + i, self.n_heads, self.rope_theta);
        }
        let mut ctx = Tensor2::zeros(t, h);
        let mut scores = vec![0.0f32; t];
        for head in 0..self.n_heads {
            let base = head * d_head;
            for i in 0..t {
                let qi = &q.row(i)[base..base + d_head];
                for (j, s) in scores.iter_mut().enumerate().take(i + 1) {
                    let kj = &k.row(j)[base..base + d_head];
                    *s = qi.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
                softmax(&mut scores[..i + 1]);
                let orow = ctx.row_mut(i);
                for j in 0..=i {
                    let w = scores[j];
                    let vj = &v.row(j)[base..base + d_head];
                    for (d, &vv) in vj.iter().enumerate() {
                        orow[base + d] += w * vv;
                    }
                }
            }
        }
        ctx.matmul(&self.wo)
    }

    /// Single-token decode: append this position's K/V to `cache`, attend
    /// over the whole cache. `x` is the `[H]` input row at absolute
    /// position `cache.len()`.
    pub fn forward_step(&self, x: &[f32], cache: &mut KvCache) -> Vec<f32> {
        let h = x.len();
        let d_head = h / self.n_heads;
        let scale = 1.0 / (d_head as f32).sqrt();
        let pos = cache.len();
        let mut q = mat_vec(&self.wq, x);
        let mut k = mat_vec(&self.wk, x);
        let v = mat_vec(&self.wv, x);
        rope(&mut q, pos, self.n_heads, self.rope_theta);
        rope(&mut k, pos, self.n_heads, self.rope_theta);
        cache.k.push(k);
        cache.v.push(v);
        let t = cache.len();
        let mut ctx = vec![0.0f32; h];
        let mut scores = vec![0.0f32; t];
        for head in 0..self.n_heads {
            let base = head * d_head;
            let qh = &q[base..base + d_head];
            for j in 0..t {
                let kj = &cache.k[j][base..base + d_head];
                scores[j] = qh.iter().zip(kj).map(|(a, b)| a * b).sum::<f32>() * scale;
            }
            softmax(&mut scores[..t]);
            for j in 0..t {
                let w = scores[j];
                let vj = &cache.v[j][base..base + d_head];
                for (d, &vv) in vj.iter().enumerate() {
                    ctx[base + d] += w * vv;
                }
            }
        }
        mat_vec(&self.wo, &ctx)
    }

    pub fn n_params(&self) -> usize {
        4 * self.wq.data.len()
    }
}

/// `w^T`-free row-major mat-vec: `y[j] = Σ_k x[k] * w[k, j]`.
pub fn mat_vec(w: &Tensor2, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; w.cols];
    for (k, &xk) in x.iter().enumerate() {
        if xk == 0.0 {
            continue;
        }
        crate::tensor::axpy(xk, w.row(k), &mut y);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn step_matches_full_sequence() {
        prop::for_all(51, 10, |rng, _| {
            let (h, heads, t) = (32, 4, 1 + rng.below(12));
            let attn = Attention::new(h, heads, 10_000.0, rng);
            let x = Tensor2::randn(t, h, rng, 1.0);
            let full = attn.forward(&x, 0);
            let mut cache = KvCache::default();
            for i in 0..t {
                let step = attn.forward_step(x.row(i), &mut cache);
                for (a, b) in step.iter().zip(full.row(i)) {
                    assert!((a - b).abs() < 1e-4, "pos {i}: {a} vs {b}");
                }
            }
            assert_eq!(cache.len(), t);
        });
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Rng::new(5);
        let mut x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        rope(&mut x, 17, 4, 10_000.0);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-3);
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let mut rng = Rng::new(6);
        let x0: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
        let mut x = x0.clone();
        rope(&mut x, 0, 2, 10_000.0);
        for (a, b) in x.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn causal_prefix_invariance() {
        // output at position i must not depend on tokens after i
        let mut rng = Rng::new(7);
        let attn = Attention::new(16, 2, 10_000.0, &mut rng);
        let x = Tensor2::randn(6, 16, &mut rng, 1.0);
        let full = attn.forward(&x, 0);
        let prefix = Tensor2::from_vec(3, 16, x.data[..3 * 16].to_vec());
        let part = attn.forward(&prefix, 0);
        for i in 0..3 {
            for (a, b) in part.row(i).iter().zip(full.row(i)) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }
}
