//! Expert-grouped batched dispatch — the single routing/execution path
//! shared by the reference forward (`MoeModel::forward_opts`, backing
//! every perplexity/LM/VLM eval, PMQ calibration and OTP distillation
//! pass) and the serving decode engine (`DecodeEngine::step`).
//!
//! Given one layer's block of post-norm token rows, [`dispatch_moe_layer`]
//! routes every row, applies the optional [`Pruner`], renormalizes the
//! kept weights, feeds the stats/counter/capture hooks, builds per-expert
//! `(row, weight)` groups, gathers each group into a contiguous block,
//! executes each expert **once** over its block, and scatters the
//! weighted outputs back into the residual rows.
//!
//! Executing per *group* instead of per *token* is what makes the paper's
//! Table 5/8 memory-and-latency wins reachable from every call site: a
//! quantized expert's packed weight tiles are decoded once per token
//! group rather than once per token (see `QuantLinear::matmul_acc`), and
//! independent expert groups within a layer run in parallel on scoped
//! threads. Group outputs are scattered in deterministic (expert-index,
//! then shared) order after the join, so results are bitwise identical
//! whether groups ran sequentially or in parallel.

use std::time::Instant;

use anyhow::Result;

use crate::tensor::Tensor2;

use super::gating::route;
use super::model::{ExpertId, Pruner};
use super::stats::RoutingStats;

/// Batch-level expert execution the dispatcher drives. `Sync` because
/// independent expert groups execute on scoped threads.
///
/// [`ProviderExec`] adapts any `ExpertProvider` (eval paths); the decode
/// engine adapts its `ExpertBackend` (native / PJRT serving paths).
pub trait DispatchExecutor: Sync {
    /// `out.row(i) += weights[i] * F_e(x.row(i))` for expert `id` of
    /// `layer`. `out` arrives zeroed, shaped like `x`.
    fn expert_batch_acc(
        &self,
        layer: usize,
        id: ExpertId,
        x: &Tensor2,
        weights: &[f32],
        out: &mut Tensor2,
    ) -> Result<()>;

    /// Packed bytes streamed when this expert executes once (serving
    /// metrics; 0 where untracked).
    fn expert_bytes(&self, _layer: usize, _id: ExpertId) -> u64 {
        0
    }

    /// Pre-execute phase: called once per layer with the deduplicated
    /// routed expert set, after gather and before the (possibly
    /// scoped-thread) execute. Paging executors make the set resident
    /// here in one batched pass — so storage I/O never sits inside the
    /// parallel region — and may prefetch the next layer.
    fn prepare(&self, _layer: usize, _routed: &[usize]) -> Result<()> {
        Ok(())
    }
}

/// [`DispatchExecutor`] over an [`ExpertProvider`](super::model::ExpertProvider)
/// — the eval-path adapter (fp weights, quantized provider, ε probes).
pub struct ProviderExec<'a>(pub &'a dyn super::model::ExpertProvider);

impl DispatchExecutor for ProviderExec<'_> {
    fn expert_batch_acc(
        &self,
        layer: usize,
        id: ExpertId,
        x: &Tensor2,
        weights: &[f32],
        out: &mut Tensor2,
    ) -> Result<()> {
        self.0.expert_ffn_batch_acc(layer, id, x, weights, out);
        Ok(())
    }

    fn prepare(&self, layer: usize, routed: &[usize]) -> Result<()> {
        self.0.ensure_resident(layer, routed)
    }
}

/// Mutable hook bundle threaded through the routing phase (all calls
/// happen on the caller's thread, token-row order, before any expert
/// executes — so hook call order matches the historical per-token path).
#[derive(Default)]
pub struct DispatchHooks<'h, 'p> {
    /// Routing statistics (PMQ §3.2.2): per kept expert `record(layer,
    /// expert, pre-renormalization weight)`, plus one `bump_tokens()` per
    /// row on layer 0.
    pub stats: Option<&'h mut RoutingStats>,
    /// Token-wise dynamic pruning (OTP/ODP/random); `keep` is clamped to
    /// `[1, k]`.
    pub pruner: Option<&'h mut (dyn Pruner + 'p)>,
    /// Accumulates (kept, offered) per token-layer (Table 6 accounting).
    pub pruning_counter: Option<&'h mut (u64, u64)>,
    /// PMQ calibration capture: `capture[layer].push(x_row)`, pre-sized
    /// to `n_layers` empty vecs.
    pub capture_moe_inputs: Option<&'h mut Vec<Vec<Vec<f32>>>>,
}

/// Per-layer dispatch accounting, returned to the caller (the engine
/// folds it into its serving metrics and phase histograms/spans; eval
/// callers may ignore it).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DispatchOutcome {
    /// Σ kept experts over rows.
    pub kept: u64,
    /// Σ offered (top-k) experts over rows.
    pub offered: u64,
    /// Σ packed bytes of each routed expert executed (once per group).
    pub routed_bytes: u64,
    /// Routing + pruning phase wall time (µs). All four phase timings
    /// are 0 for an empty block (no `Instant` reads, so the no-op
    /// equality contract holds).
    pub route_us: u64,
    /// Gather phase wall time (µs) — building each group's row block.
    pub gather_us: u64,
    /// Pre-execute residency wall time (µs) — expert paging and remote
    /// FETCH wait live here.
    pub prepare_us: u64,
    /// Execute + scatter phase wall time (µs).
    pub execute_us: u64,
}

/// One gathered expert group ready to execute.
struct GroupWork {
    id: ExpertId,
    /// Residual row index per gathered row.
    rows: Vec<usize>,
    weights: Vec<f32>,
    /// `[G, H]` gathered input rows; `None` means the group covers the
    /// whole block in order (shared experts) and `normed` is borrowed
    /// directly instead of copied.
    x: Option<Tensor2>,
}

/// Minimum total input volume (gathered rows × hidden dim, in f32s)
/// before the scoped-thread fan-out pays for its spawn cost. Each row
/// costs ~3·H·F FLOPs in the expert FFN, so at H=128 this threshold
/// (~32 rows) corresponds to a few milliseconds of work; below it the
/// per-layer thread spawns dominate (tiny test models, 1–2 sequence
/// decode steps) and groups run inline.
const PAR_MIN_VOLUME: usize = 4096;

/// Route + prune + group + execute + scatter one MoE layer.
///
/// * `normed` — `[T, H]` post-norm token rows for this layer;
/// * `residual` — `[T, H]` stream the weighted expert outputs accumulate
///   into (row-aligned with `normed`);
/// * shared experts run as whole-block groups with unit weights after
///   the routed groups, preserving the historical routed-then-shared
///   accumulation order.
#[allow(clippy::too_many_arguments)]
pub fn dispatch_moe_layer(
    layer: usize,
    gate: &Tensor2,
    top_k: usize,
    n_shared: usize,
    normed: &Tensor2,
    exec: &dyn DispatchExecutor,
    hooks: &mut DispatchHooks,
    residual: &mut Tensor2,
) -> Result<DispatchOutcome> {
    let t = normed.rows;
    let h = normed.cols;
    let n_experts = gate.cols;
    let mut outcome = DispatchOutcome::default();
    // phase boundaries (µs timings land in the outcome; the engine turns
    // them into step-phase histograms and timeline spans)
    let t_route = Instant::now();
    // -- routing phase: sequential, hook order == token-row order --------
    let mut groups: Vec<Vec<(usize, f32)>> = vec![Vec::new(); n_experts];
    for i in 0..t {
        let xin = normed.row(i);
        if let Some(cap) = hooks.capture_moe_inputs.as_deref_mut() {
            cap[layer].push(xin.to_vec());
        }
        let r = route(xin, gate, top_k);
        let keep = match hooks.pruner.as_deref_mut() {
            Some(p) => p.keep(layer, xin, &r).clamp(1, r.experts.len()),
            None => r.experts.len(),
        };
        if let Some(counter) = hooks.pruning_counter.as_deref_mut() {
            counter.0 += keep as u64;
            counter.1 += r.experts.len() as u64;
        }
        outcome.kept += keep as u64;
        outcome.offered += r.experts.len() as u64;
        // renormalize kept weights (pruned experts' mass is redistributed)
        let wsum: f32 = r.weights[..keep].iter().sum();
        for rank in 0..keep {
            let e = r.experts[rank];
            if let Some(stats) = hooks.stats.as_deref_mut() {
                stats.record(layer, e, r.weights[rank]);
            }
            groups[e].push((i, r.weights[rank] / wsum));
        }
        if layer == 0 {
            if let Some(stats) = hooks.stats.as_deref_mut() {
                stats.bump_tokens();
            }
        }
    }
    let t_gather = Instant::now();
    // -- gather phase ----------------------------------------------------
    let mut work: Vec<GroupWork> = Vec::new();
    for (e, group) in groups.iter().enumerate() {
        if group.is_empty() {
            continue;
        }
        outcome.routed_bytes += exec.expert_bytes(layer, ExpertId::Routed(e));
        let mut xg = Tensor2::zeros(group.len(), h);
        for (gi, &(row, _)) in group.iter().enumerate() {
            xg.row_mut(gi).copy_from_slice(normed.row(row));
        }
        work.push(GroupWork {
            id: ExpertId::Routed(e),
            rows: group.iter().map(|&(r, _)| r).collect(),
            weights: group.iter().map(|&(_, w)| w).collect(),
            x: Some(xg),
        });
    }
    if t > 0 {
        for s in 0..n_shared {
            work.push(GroupWork {
                id: ExpertId::Shared(s),
                rows: (0..t).collect(),
                weights: vec![1.0; t],
                x: None,
            });
        }
    }
    // -- pre-execute phase: batched residency for the routed set ---------
    // (paging I/O happens here, on the caller's thread, never inside the
    // scoped-thread execute; the store may also prefetch layer+1)
    let routed: Vec<usize> = work
        .iter()
        .filter_map(|g| match g.id {
            ExpertId::Routed(e) => Some(e),
            ExpertId::Shared(_) => None,
        })
        .collect();
    let t_prepare = Instant::now();
    exec.prepare(layer, &routed)?;
    let t_execute = Instant::now();
    // -- execute phase: each expert once over its gathered block ---------
    let blocks = run_groups(layer, exec, normed, &work)?;
    // -- scatter phase: deterministic group order, weights pre-applied ---
    for (gw, block) in work.iter().zip(&blocks) {
        for (gi, &row) in gw.rows.iter().enumerate() {
            let xr = residual.row_mut(row);
            for (a, o) in xr.iter_mut().zip(block.row(gi)) {
                *a += o;
            }
        }
    }
    // an empty block keeps every timing at 0 so the no-op equality
    // contract (`outcome == DispatchOutcome::default()`) still holds
    if t > 0 {
        outcome.route_us = t_gather.duration_since(t_route).as_micros() as u64;
        outcome.gather_us = t_prepare.duration_since(t_gather).as_micros() as u64;
        outcome.prepare_us = t_execute.duration_since(t_prepare).as_micros() as u64;
        outcome.execute_us = t_execute.elapsed().as_micros() as u64;
    }
    Ok(outcome)
}

/// Execute every group, fanning independent groups out over scoped
/// threads when the layer carries enough rows to pay for it.
// analyze: hot-path
fn run_groups(
    layer: usize,
    exec: &dyn DispatchExecutor,
    normed: &Tensor2,
    work: &[GroupWork],
) -> Result<Vec<Tensor2>> {
    let run_one = |g: &GroupWork| -> Result<Tensor2> {
        let xb = g.x.as_ref().unwrap_or(normed);
        let mut out = Tensor2::zeros(xb.rows, xb.cols);
        exec.expert_batch_acc(layer, g.id, xb, &g.weights, &mut out)?;
        Ok(out)
    };
    let n = work.len();
    let total_rows: usize = work.iter().map(|g| g.rows.len()).sum();
    let workers = std::thread::available_parallelism()
        .map(|v| v.get())
        .unwrap_or(1)
        .min(n);
    if workers <= 1 || total_rows * normed.cols < PAR_MIN_VOLUME {
        // analyze: allow(alloc): one output block per expert group —
        // these ARE the layer's results, sized by routing each step
        return work.iter().map(run_one).collect();
    }
    // analyze: allow(alloc): one slot per expert group per layer step
    let mut blocks: Vec<Option<Result<Tensor2>>> = Vec::with_capacity(n);
    blocks.resize_with(n, || None);
    std::thread::scope(|s| {
        // analyze: allow(alloc): one join handle per worker thread
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let run_one = &run_one;
            handles.push(s.spawn(move || {
                // analyze: allow(alloc): per-worker result list, |groups|/workers entries
                let mut outs = Vec::new();
                let mut gi = w;
                while gi < n {
                    outs.push((gi, run_one(&work[gi])));
                    gi += workers;
                }
                outs
            }));
        }
        for handle in handles {
            for (gi, r) in handle.join().expect("dispatch worker panicked") {
                blocks[gi] = Some(r);
            }
        }
    });
    blocks
        .into_iter()
        .map(|b| b.expect("every group index is covered by exactly one worker"))
        // analyze: allow(alloc): final unwrap of the per-group blocks
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::moe::gating::Route;
    use crate::moe::model::{ExpertProvider, MoeModel};
    use crate::util::rng::Rng;

    fn cfg(n_shared: usize) -> ModelConfig {
        ModelConfig {
            name: "dispatch-test".into(),
            family: "mixtral".into(),
            vocab_size: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 48,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: n_shared,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        }
    }

    /// Per-token reference: the historical row-at-a-time MoE layer.
    fn reference_layer(
        m: &MoeModel,
        layer: usize,
        normed: &Tensor2,
        keep_of: impl Fn(usize) -> usize,
        residual: &mut Tensor2,
    ) {
        let block = &m.blocks[layer];
        for i in 0..normed.rows {
            let xin = normed.row(i);
            let r = route(xin, &block.gate, m.cfg.top_k);
            let keep = keep_of(i).clamp(1, r.experts.len());
            let wsum: f32 = r.weights[..keep].iter().sum();
            let acc = residual.row_mut(i);
            for rank in 0..keep {
                block.experts[r.experts[rank]].ffn_row_acc(xin, r.weights[rank] / wsum, acc);
            }
            for shared in &block.shared {
                shared.ffn_row_acc(xin, 1.0, acc);
            }
        }
    }

    #[test]
    fn grouped_matches_per_token_reference() {
        let m = MoeModel::new(&cfg(1), 90);
        let mut rng = Rng::new(91);
        // 128 rows x 32 dims crosses PAR_MIN_VOLUME, so the scoped-thread
        // path engages wherever the host has >1 core
        let normed = Tensor2::randn(128, 32, &mut rng, 1.0);
        let mut want = Tensor2::zeros(128, 32);
        reference_layer(&m, 1, &normed, |_| usize::MAX, &mut want);
        let mut got = Tensor2::zeros(128, 32);
        let exec = ProviderExec(&m);
        let out = dispatch_moe_layer(
            1,
            &m.blocks[1].gate,
            2,
            1,
            &normed,
            &exec,
            &mut DispatchHooks::default(),
            &mut got,
        )
        .unwrap();
        assert_eq!(out.offered, 128 * 2);
        assert_eq!(out.kept, out.offered);
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn pruner_and_hooks_fire_in_row_order() {
        struct SeqPruner {
            seen: Vec<usize>,
        }
        impl Pruner for SeqPruner {
            fn keep(&mut self, _l: usize, _x: &[f32], r: &Route) -> usize {
                self.seen.push(r.experts[0]);
                1 + self.seen.len() % 2
            }
        }
        let m = MoeModel::new(&cfg(0), 92);
        let mut rng = Rng::new(93);
        let normed = Tensor2::randn(6, 32, &mut rng, 1.0);
        let mut pruner = SeqPruner { seen: Vec::new() };
        let mut stats = RoutingStats::new(2, 4);
        let mut counter = (0u64, 0u64);
        let mut cap: Vec<Vec<Vec<f32>>> = vec![Vec::new(); 2];
        let mut residual = Tensor2::zeros(6, 32);
        let exec = ProviderExec(&m);
        let mut hooks = DispatchHooks {
            stats: Some(&mut stats),
            pruner: Some(&mut pruner),
            pruning_counter: Some(&mut counter),
            capture_moe_inputs: Some(&mut cap),
        };
        let out =
            dispatch_moe_layer(0, &m.blocks[0].gate, 2, 0, &normed, &exec, &mut hooks, &mut residual)
                .unwrap();
        assert_eq!(pruner.seen.len(), 6, "pruner consulted once per row");
        assert_eq!(counter, (out.kept, out.offered));
        assert_eq!(stats.tokens, 6, "layer-0 dispatch bumps tokens per row");
        assert_eq!(cap[0].len(), 6);
        assert!(cap[1].is_empty());
        for (i, x) in cap[0].iter().enumerate() {
            assert_eq!(x.as_slice(), normed.row(i), "capture preserves row order");
        }
        let recorded: u64 = (0..4).map(|e| stats.counts[e]).sum();
        assert_eq!(recorded, out.kept, "stats record only kept experts");
    }

    /// The pre-execute phase must hand the full deduplicated routed set
    /// to the executor before any expert runs (the paging contract).
    #[test]
    fn prepare_precedes_every_execute() {
        struct Tracking<'a> {
            inner: ProviderExec<'a>,
            log: std::sync::Mutex<Vec<String>>,
        }
        impl DispatchExecutor for Tracking<'_> {
            fn expert_batch_acc(
                &self,
                layer: usize,
                id: ExpertId,
                x: &Tensor2,
                weights: &[f32],
                out: &mut Tensor2,
            ) -> Result<()> {
                self.log.lock().unwrap().push(format!("exec {id:?}"));
                self.inner.expert_batch_acc(layer, id, x, weights, out)
            }
            fn prepare(&self, _layer: usize, routed: &[usize]) -> Result<()> {
                self.log.lock().unwrap().push(format!("prepare {routed:?}"));
                Ok(())
            }
        }
        let m = MoeModel::new(&cfg(1), 99);
        let mut rng = Rng::new(100);
        // 6x32 stays under PAR_MIN_VOLUME: sequential execute, stable log
        let normed = Tensor2::randn(6, 32, &mut rng, 1.0);
        let mut residual = Tensor2::zeros(6, 32);
        let exec = Tracking { inner: ProviderExec(&m), log: std::sync::Mutex::new(Vec::new()) };
        dispatch_moe_layer(
            0,
            &m.blocks[0].gate,
            2,
            1,
            &normed,
            &exec,
            &mut DispatchHooks::default(),
            &mut residual,
        )
        .unwrap();
        let log = exec.log.into_inner().unwrap();
        assert!(log[0].starts_with("prepare ["), "first event {:?}", log[0]);
        assert!(log.iter().skip(1).all(|l| l.starts_with("exec")));
        // routed set is deduplicated and ascending (group order)
        let routed: Vec<usize> = log[0]
            .trim_start_matches("prepare [")
            .trim_end_matches(']')
            .split(", ")
            .filter(|s| !s.is_empty())
            .map(|s| s.parse().unwrap())
            .collect();
        let mut sorted = routed.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(routed, sorted);
        assert!(!routed.is_empty());
    }

    #[test]
    fn empty_block_is_a_no_op() {
        let m = MoeModel::new(&cfg(1), 94);
        let normed = Tensor2::zeros(0, 32);
        let mut residual = Tensor2::zeros(0, 32);
        let exec = ProviderExec(&m);
        let out = dispatch_moe_layer(
            0,
            &m.blocks[0].gate,
            2,
            1,
            &normed,
            &exec,
            &mut DispatchHooks::default(),
            &mut residual,
        )
        .unwrap();
        assert_eq!(out, DispatchOutcome::default());
    }

    #[test]
    fn executor_errors_propagate() {
        struct Failing;
        impl DispatchExecutor for Failing {
            fn expert_batch_acc(
                &self,
                _layer: usize,
                _id: ExpertId,
                _x: &Tensor2,
                _weights: &[f32],
                _out: &mut Tensor2,
            ) -> Result<()> {
                Err(anyhow::anyhow!("backend down"))
            }
        }
        let m = MoeModel::new(&cfg(0), 95);
        let mut rng = Rng::new(96);
        let normed = Tensor2::randn(8, 32, &mut rng, 1.0);
        let mut residual = Tensor2::zeros(8, 32);
        let err = dispatch_moe_layer(
            0,
            &m.blocks[0].gate,
            2,
            0,
            &normed,
            &Failing,
            &mut DispatchHooks::default(),
            &mut residual,
        );
        assert!(err.is_err());
    }

    /// The degenerate-row default of `ExpertProvider` and an explicit
    /// batch override must agree (the trait's two faces).
    #[test]
    fn provider_row_and_batch_defaults_agree() {
        let m = MoeModel::new(&cfg(1), 97);
        let mut rng = Rng::new(98);
        let x = Tensor2::randn(3, 32, &mut rng, 1.0);
        let weights = [0.25f32, 1.0, 0.5];
        let mut batch_out = Tensor2::zeros(3, 32);
        m.expert_ffn_batch_acc(0, ExpertId::Routed(1), &x, &weights, &mut batch_out);
        for i in 0..3 {
            let mut row_out = vec![0.0f32; 32];
            m.expert_ffn_acc(0, ExpertId::Routed(1), x.row(i), weights[i], &mut row_out);
            for (a, b) in batch_out.row(i).iter().zip(&row_out) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }
}
