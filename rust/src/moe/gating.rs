//! Top-k softmax routing (paper Eq. 1).
//!
//! `route` returns the top-k experts for one token, **rank-sorted by
//! routing weight descending** and renormalized to sum to 1 — the same
//! ordering contract the OTP candidate masks C_k rely on (Eq. 10 prunes
//! from the lowest-ranked expert upward).

use crate::tensor::{softmax, top_k_indices, Tensor2};

/// Routing decision for one token.
#[derive(Clone, Debug, PartialEq)]
pub struct Route {
    /// Expert indices, rank-sorted by weight descending, length k.
    pub experts: Vec<usize>,
    /// Renormalized weights aligned with `experts`, summing to 1.
    pub weights: Vec<f32>,
    /// Full softmax scores over all experts (needed by stats & aux loss).
    pub scores: Vec<f32>,
}

/// Route one token `x` through gate matrix `[H, E]`.
pub fn route(x: &[f32], gate: &Tensor2, k: usize) -> Route {
    let e = gate.cols;
    let mut scores = vec![0.0f32; e];
    for (kk, &xk) in x.iter().enumerate() {
        if xk == 0.0 {
            continue;
        }
        let row = gate.row(kk);
        for j in 0..e {
            scores[j] += xk * row[j];
        }
    }
    softmax(&mut scores);
    let experts = top_k_indices(&scores, k);
    let mut weights: Vec<f32> = experts.iter().map(|&i| scores[i]).collect();
    let sum: f32 = weights.iter().sum();
    for w in weights.iter_mut() {
        *w /= sum;
    }
    Route { experts, weights, scores }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn weights_sorted_and_normalized() {
        prop::for_all(41, 30, |rng, _| {
            let (h, e) = (16, 2 + rng.below(14));
            let k = 1 + rng.below(e.min(6));
            let gate = Tensor2::randn(h, e, rng, 1.0);
            let x: Vec<f32> = (0..h).map(|_| rng.normal()).collect();
            let r = route(&x, &gate, k);
            assert_eq!(r.experts.len(), k);
            assert!((r.weights.iter().sum::<f32>() - 1.0).abs() < 1e-5);
            for w in r.weights.windows(2) {
                assert!(w[0] >= w[1] - 1e-6, "not rank-sorted");
            }
            // experts unique
            let mut uniq = r.experts.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), k);
            // scores form a distribution
            assert!((r.scores.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        });
    }

    #[test]
    fn picks_argmax_expert_first() {
        let mut rng = Rng::new(42);
        let gate = Tensor2::randn(8, 4, &mut rng, 1.0);
        let x: Vec<f32> = (0..8).map(|_| rng.normal()).collect();
        let r = route(&x, &gate, 2);
        let best = r
            .scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(r.experts[0], best);
    }
}
