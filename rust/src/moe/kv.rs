//! Paged KV cache with prefix sharing — the PagedStore idea (PR 2)
//! applied to activation memory.
//!
//! The serving engine used to own KV per sequence as `Vec<Vec<f32>>`
//! rows: no reuse across requests, O(positions) byte accounting, and a
//! full re-prefill for every prompt. This module replaces that with a
//! single [`KvPool`] per engine:
//!
//! * **Pages** — K and V for a fixed number of positions
//!   ([`KvPool::page_positions`], default [`DEFAULT_KV_PAGE`]) live in
//!   one refcounted slab. Freed pages go on a free-list and are
//!   recycled buffer-and-all, so steady-state serving stops allocating.
//! * **Page tables** — a sequence holds [`LayerKv`] (page ids + length)
//!   per layer instead of owning rows. The attention read path walks
//!   pages ([`KvPool::walk`]).
//! * **Prefix tree** — every *full* block a sequence completes is
//!   registered under the chain of token-blocks that precedes it
//!   (KV at position p depends on the entire prefix, so the tree path
//!   — not a flat block hash — is the correct key). A new request walks
//!   the tree with its prompt and adopts the pages of every matching
//!   leading block: refcount bump, zero copy, and the engine skips
//!   prefilling those positions entirely. A trailing partial match is
//!   adopted too; the first divergent append then copies the shared
//!   rows (copy-on-write).
//! * **O(1) accounting** — bytes = pages-in-use × page bytes; the
//!   engine republishes [`KvGauges`] every step without touching pages.
//!
//! Sharing is sound because a page is immutable once full (RoPE'd K
//! rows are absolute-position, so the same token prefix produces the
//! same KV) and copy-on-write isolates writers of partial pages.

const NO_NODE: usize = usize::MAX;

/// Default positions per KV page (`--kv-page`). Matches the fused
/// matmul sweet spot measured in `perf_hotpath` §kernels.
pub const DEFAULT_KV_PAGE: usize = 16;

/// One KV page: K and V for up to `page` positions × `width` floats.
struct Page {
    k: Vec<f32>,
    v: Vec<f32>,
    rc: u32,
}

/// Per-layer page table of one sequence: page ids + filled positions.
#[derive(Debug, Default)]
pub struct LayerKv {
    pages: Vec<usize>,
    len: usize,
}

impl LayerKv {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// All KV state of one sequence: one [`LayerKv`] per layer plus the
/// prefix-tree cursor used to register completed blocks.
#[derive(Debug)]
pub struct SeqKv {
    pub layers: Vec<LayerKv>,
    /// Prompt tokens covered by *full* shared blocks at admission —
    /// these pages are charged to the prefix tree, not to this
    /// sequence's token-budget footprint.
    shared_toks: usize,
    /// Full blocks already present in (or registered into) the tree.
    registered: usize,
    /// Deepest tree node whose block chain this sequence sits under.
    node: usize,
}

impl SeqKv {
    pub fn new(n_layers: usize) -> SeqKv {
        SeqKv {
            layers: (0..n_layers).map(|_| LayerKv::default()).collect(),
            shared_toks: 0,
            registered: 0,
            node: NO_NODE,
        }
    }

    /// Cached positions (layer 0 is canonical; all layers agree
    /// between engine steps).
    pub fn len(&self) -> usize {
        self.layers.first().map_or(0, |l| l.len)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Prompt tokens adopted as full shared blocks (budget discount).
    pub fn shared_toks(&self) -> usize {
        self.shared_toks
    }
}

/// One registered block: `tokens` (exactly one page worth) reached by
/// the chain of blocks above it, holding one page per layer.
struct Node {
    hash: u64,
    tokens: Vec<u16>,
    pages: Vec<usize>,
    children: Vec<usize>,
    parent: usize,
    last_used: u64,
    alive: bool,
}

/// O(1) snapshot published into METRICS/STATS every engine step.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvGauges {
    /// Pages currently in use (refcount > 0).
    pub kv_pages: u64,
    /// Bytes held by in-use pages (pages × page bytes).
    pub kv_bytes: u64,
    /// Lifetime prompt tokens whose KV was adopted from the prefix
    /// tree instead of being prefilled.
    pub prefix_hit_toks: u64,
    /// Lifetime copy-on-write page copies (first divergent append).
    pub cow_copies: u64,
    /// Live blocks in the prefix tree.
    pub tree_blocks: u64,
}

pub struct KvPool {
    page: usize,
    width: usize,
    n_layers: usize,
    pages: Vec<Page>,
    free: Vec<usize>,
    nodes: Vec<Node>,
    free_nodes: Vec<usize>,
    root_children: Vec<usize>,
    /// Soft cap on pages in use; tree-only pages are evicted (LRU
    /// leaves first) to get back under it. 0 = unbounded.
    page_cap: usize,
    clock: u64,
    prefix_hit_toks: u64,
    cow_copies: u64,
    live_nodes: u64,
}

fn block_hash(tokens: &[u16]) -> u64 {
    // FNV-1a over the token words
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in tokens {
        h ^= t as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl KvPool {
    pub fn new(page: usize, width: usize, n_layers: usize) -> KvPool {
        KvPool {
            page: page.max(1),
            width,
            n_layers,
            pages: Vec::new(),
            free: Vec::new(),
            nodes: Vec::new(),
            free_nodes: Vec::new(),
            root_children: Vec::new(),
            page_cap: 0,
            clock: 0,
            prefix_hit_toks: 0,
            cow_copies: 0,
            live_nodes: 0,
        }
    }

    pub fn page_positions(&self) -> usize {
        self.page
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Cap pages-in-use; the tree sheds LRU leaf blocks to fit.
    pub fn set_page_cap(&mut self, cap: usize) {
        self.page_cap = cap;
        self.trim();
    }

    fn page_nbytes(&self) -> u64 {
        (2 * self.page * self.width * std::mem::size_of::<f32>()) as u64
    }

    /// Pages currently referenced by sequences or the tree. O(1).
    pub fn pages_in_use(&self) -> usize {
        self.pages.len() - self.free.len()
    }

    /// Bytes held by in-use pages. O(1) — no page is ever touched.
    pub fn nbytes(&self) -> u64 {
        self.pages_in_use() as u64 * self.page_nbytes()
    }

    pub fn gauges(&self) -> KvGauges {
        KvGauges {
            kv_pages: self.pages_in_use() as u64,
            kv_bytes: self.nbytes(),
            prefix_hit_toks: self.prefix_hit_toks,
            cow_copies: self.cow_copies,
            tree_blocks: self.live_nodes,
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn alloc_page(&mut self) -> usize {
        if let Some(id) = self.free.pop() {
            self.pages[id].rc = 1;
            return id;
        }
        let n = self.page * self.width;
        self.pages.push(Page { k: vec![0.0; n], v: vec![0.0; n], rc: 1 });
        self.pages.len() - 1
    }

    fn retain(&mut self, id: usize) {
        self.pages[id].rc += 1;
    }

    fn release(&mut self, id: usize) {
        let p = &mut self.pages[id];
        debug_assert!(p.rc > 0, "double free of kv page {id}");
        p.rc -= 1;
        if p.rc == 0 {
            self.free.push(id);
        }
    }

    /// Append one position's K and V rows to `lk`, allocating a page at
    /// block boundaries and copy-on-writing a shared partial page.
    pub fn append(&mut self, lk: &mut LayerKv, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.width);
        debug_assert_eq!(v.len(), self.width);
        let off = lk.len % self.page;
        if off == 0 {
            let id = self.alloc_page();
            lk.pages.push(id);
        }
        let b = lk.len / self.page;
        let mut id = lk.pages[b];
        if self.pages[id].rc > 1 {
            // first divergent write into an adopted page: copy the
            // shared rows into a private page, drop our shared ref
            let nid = self.alloc_page();
            let n = off * self.width;
            let (src, dst) = twin(&mut self.pages, id, nid);
            dst.k[..n].copy_from_slice(&src.k[..n]);
            dst.v[..n].copy_from_slice(&src.v[..n]);
            self.release(id);
            lk.pages[b] = nid;
            id = nid;
            self.cow_copies += 1;
        }
        let at = off * self.width;
        self.pages[id].k[at..at + self.width].copy_from_slice(k);
        self.pages[id].v[at..at + self.width].copy_from_slice(v);
        lk.len += 1;
    }

    /// K and V rows of position `pos`.
    pub fn row(&self, lk: &LayerKv, pos: usize) -> (&[f32], &[f32]) {
        debug_assert!(pos < lk.len);
        let p = &self.pages[lk.pages[pos / self.page]];
        let at = (pos % self.page) * self.width;
        (&p.k[at..at + self.width], &p.v[at..at + self.width])
    }

    /// Walk positions `0..t` in order, calling `f(pos, k_row, v_row)`.
    /// One page lookup per block, not per position — the attention
    /// decode read path.
    pub fn walk(&self, lk: &LayerKv, t: usize, mut f: impl FnMut(usize, &[f32], &[f32])) {
        debug_assert!(t <= lk.len);
        let mut pos = 0;
        for &pid in &lk.pages {
            if pos >= t {
                break;
            }
            let page = &self.pages[pid];
            let n = self.page.min(t - pos);
            for r in 0..n {
                let at = r * self.width;
                f(pos + r, &page.k[at..at + self.width], &page.v[at..at + self.width]);
            }
            pos += n;
        }
    }

    /// Release every page the sequence holds and reset its tables.
    /// Pages also referenced by the tree (or other sequences) survive.
    pub fn free_seq(&mut self, kv: &mut SeqKv) {
        for l in 0..kv.layers.len() {
            for b in 0..kv.layers[l].pages.len() {
                self.release(kv.layers[l].pages[b]);
            }
            kv.layers[l].pages.clear();
            kv.layers[l].len = 0;
        }
        kv.shared_toks = 0;
        kv.registered = 0;
        kv.node = NO_NODE;
        self.trim();
    }

    fn children_of(&self, node: usize) -> &[usize] {
        if node == NO_NODE {
            &self.root_children
        } else {
            &self.nodes[node].children
        }
    }

    fn find_child(&self, node: usize, blk: &[u16]) -> Option<usize> {
        let h = block_hash(blk);
        self.children_of(node)
            .iter()
            .copied()
            .find(|&c| self.nodes[c].hash == h && self.nodes[c].tokens == blk)
    }

    fn find_child_prefix(&self, node: usize, rem: &[u16]) -> Option<usize> {
        self.children_of(node)
            .iter()
            .copied()
            .find(|&c| self.nodes[c].tokens.starts_with(rem))
    }

    /// Read-only admission probe: prompt tokens a [`lookup_prefix`]
    /// would cover with *full* shared blocks (the token-budget
    /// discount). `lookup_prefix` under the same pool lock adopts
    /// exactly these.
    ///
    /// [`lookup_prefix`]: KvPool::lookup_prefix
    pub fn probe_prefix(&self, prompt: &[u16]) -> usize {
        let usable = prompt.len().saturating_sub(1);
        let mut node = NO_NODE;
        let mut m = 0;
        while m + self.page <= usable {
            match self.find_child(node, &prompt[m..m + self.page]) {
                Some(c) => {
                    node = c;
                    m += self.page;
                }
                None => break,
            }
        }
        m
    }

    /// Map the prompt's leading blocks onto resident tree pages:
    /// refcount bump per adopted page, no copies. At most
    /// `prompt.len() - 1` positions are adopted — the engine always
    /// computes logits at the last prompt position. A trailing partial
    /// block (fewer than `page` positions) is adopted copy-on-write.
    pub fn lookup_prefix(&mut self, prompt: &[u16]) -> SeqKv {
        let mut kv = SeqKv::new(self.n_layers);
        let usable = prompt.len().saturating_sub(1);
        let mut m = 0;
        while m + self.page <= usable {
            let Some(c) = self.find_child(kv.node, &prompt[m..m + self.page]) else {
                break;
            };
            let t = self.tick();
            self.nodes[c].last_used = t;
            for l in 0..self.n_layers {
                let pid = self.nodes[c].pages[l];
                self.retain(pid);
                kv.layers[l].pages.push(pid);
            }
            kv.node = c;
            m += self.page;
        }
        for lk in &mut kv.layers {
            lk.len = m;
        }
        kv.shared_toks = m;
        kv.registered = m / self.page;
        let mut hit = m;
        let r = usable - m;
        if r > 0 && r < self.page {
            if let Some(c) = self.find_child_prefix(kv.node, &prompt[m..m + r]) {
                let t = self.tick();
                self.nodes[c].last_used = t;
                for l in 0..self.n_layers {
                    let pid = self.nodes[c].pages[l];
                    self.retain(pid);
                    kv.layers[l].pages.push(pid);
                    kv.layers[l].len += r;
                }
                // kv.node stays at the last *full* match: the partial
                // block is not a tree step, and the first append into
                // it copy-on-writes a private page.
                hit += r;
            }
        }
        self.prefix_hit_toks += hit as u64;
        kv
    }

    /// Register every newly completed block of this sequence into the
    /// prefix tree. If an identical block chain already exists the
    /// sequence adopts the tree's pages and frees its own (dedup);
    /// otherwise the tree takes a reference on the sequence's page.
    pub fn register_progress(&mut self, kv: &mut SeqKv, tokens: &[u16]) {
        let full = kv.len() / self.page;
        while kv.registered < full {
            let b = kv.registered;
            let blk = &tokens[b * self.page..(b + 1) * self.page];
            if let Some(c) = self.find_child(kv.node, blk) {
                if self.nodes[c].pages[0] != kv.layers[0].pages[b] {
                    // identical block computed independently: converge
                    // on the tree's copy, free ours
                    for l in 0..self.n_layers {
                        let theirs = self.nodes[c].pages[l];
                        let ours = kv.layers[l].pages[b];
                        self.retain(theirs);
                        self.release(ours);
                        kv.layers[l].pages[b] = theirs;
                    }
                }
                let t = self.tick();
                self.nodes[c].last_used = t;
                kv.node = c;
            } else {
                let pages: Vec<usize> = (0..self.n_layers).map(|l| kv.layers[l].pages[b]).collect();
                for &p in &pages {
                    self.retain(p);
                }
                let node = Node {
                    hash: block_hash(blk),
                    tokens: blk.to_vec(),
                    pages,
                    children: Vec::new(),
                    parent: kv.node,
                    last_used: self.clock + 1,
                    alive: true,
                };
                self.clock += 1;
                let id = if let Some(slot) = self.free_nodes.pop() {
                    self.nodes[slot] = node;
                    slot
                } else {
                    self.nodes.push(node);
                    self.nodes.len() - 1
                };
                if kv.node == NO_NODE {
                    self.root_children.push(id);
                } else {
                    self.nodes[kv.node].children.push(id);
                }
                self.live_nodes += 1;
                kv.node = id;
            }
            kv.registered += 1;
        }
    }

    /// Evict LRU leaf blocks whose pages only the tree still holds
    /// until pages-in-use fits under `page_cap`. Blocks referenced by
    /// a live sequence always have refcount ≥ 2 and are never evicted,
    /// so sequence cursors stay valid.
    fn trim(&mut self) {
        if self.page_cap == 0 {
            return;
        }
        while self.pages_in_use() > self.page_cap {
            let victim = self
                .nodes
                .iter()
                .enumerate()
                .filter(|(_, n)| {
                    n.alive
                        && n.children.is_empty()
                        && n.pages.iter().all(|&p| self.pages[p].rc == 1)
                })
                .min_by_key(|(_, n)| n.last_used)
                .map(|(i, _)| i);
            let Some(id) = victim else {
                break;
            };
            let parent = self.nodes[id].parent;
            let pages = std::mem::take(&mut self.nodes[id].pages);
            for p in pages {
                self.release(p);
            }
            self.nodes[id].alive = false;
            self.nodes[id].children = Vec::new();
            self.nodes[id].tokens = Vec::new();
            let siblings = if parent == NO_NODE {
                &mut self.root_children
            } else {
                &mut self.nodes[parent].children
            };
            if let Some(at) = siblings.iter().position(|&c| c == id) {
                siblings.swap_remove(at);
            }
            self.free_nodes.push(id);
            self.live_nodes -= 1;
        }
    }
}

/// Disjoint `&mut` to two pages (copy-on-write source and destination).
fn twin(pages: &mut [Page], a: usize, b: usize) -> (&Page, &mut Page) {
    debug_assert_ne!(a, b);
    if a < b {
        let (lo, hi) = pages.split_at_mut(b);
        (&lo[a], &mut hi[0])
    } else {
        let (lo, hi) = pages.split_at_mut(a);
        (&hi[0], &mut lo[b])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(pool: &mut KvPool, kv: &mut SeqKv, tokens: &[u16], from: usize) {
        // stand-in for prefill: deterministic rows derived from the token
        for pos in from..tokens.len() {
            for l in 0..kv.layers.len() {
                let base = tokens[pos] as f32 + l as f32 * 1000.0;
                let k: Vec<f32> = (0..pool.width).map(|i| base + i as f32).collect();
                let v: Vec<f32> = (0..pool.width).map(|i| -(base + i as f32)).collect();
                let lk = &mut kv.layers[l];
                pool.append(lk, &k, &v);
            }
        }
        pool.register_progress(kv, tokens);
    }

    #[test]
    fn append_row_roundtrip_across_pages() {
        let mut pool = KvPool::new(4, 8, 1);
        let mut kv = SeqKv::new(1);
        let tokens: Vec<u16> = (0..11).collect();
        fill(&mut pool, &mut kv, &tokens, 0);
        assert_eq!(kv.len(), 11);
        assert_eq!(kv.layers[0].pages.len(), 3); // ceil(11/4)
        for pos in 0..11 {
            let (k, v) = pool.row(&kv.layers[0], pos);
            assert_eq!(k[3], tokens[pos] as f32 + 3.0);
            assert_eq!(v[0], -(tokens[pos] as f32));
        }
        let mut seen = Vec::new();
        pool.walk(&kv.layers[0], 7, |pos, k, _| {
            assert_eq!(k[0], tokens[pos] as f32);
            seen.push(pos);
        });
        assert_eq!(seen, (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn nbytes_is_page_granular_and_o1() {
        let mut pool = KvPool::new(4, 8, 2);
        assert_eq!(pool.nbytes(), 0);
        let mut kv = SeqKv::new(2);
        fill(&mut pool, &mut kv, &(0..5).collect::<Vec<u16>>(), 0);
        // 5 positions -> 2 pages per layer x 2 layers
        assert_eq!(pool.pages_in_use(), 4);
        assert_eq!(pool.nbytes(), 4 * 2 * 4 * 8 * 4);
        assert_eq!(pool.gauges().kv_pages, 4);
    }

    #[test]
    fn free_list_recycles_pages() {
        let mut pool = KvPool::new(4, 8, 1);
        let mut peak = 0;
        for round in 0..5 {
            let mut kv = SeqKv::new(1);
            // distinct tokens per round: nothing shared, tree grows only
            // if blocks complete — use 3 positions (< page) so no
            // registration keeps pages alive
            let toks: Vec<u16> = (0..3).map(|t| t + round * 100).collect();
            fill(&mut pool, &mut kv, &toks, 0);
            peak = peak.max(pool.pages_in_use());
            pool.free_seq(&mut kv);
            assert_eq!(pool.pages_in_use(), 0);
        }
        // capacity plateaus: every round reuses round 0's single page
        assert_eq!(peak, 1);
        assert_eq!(pool.pages.len(), 1);
    }

    #[test]
    fn lookup_adopts_full_blocks_and_counts_hits() {
        let mut pool = KvPool::new(4, 8, 2);
        let prompt: Vec<u16> = (0..9).collect(); // blocks [0..4), [4..8), tail 8
        let mut a = SeqKv::new(2);
        fill(&mut pool, &mut a, &prompt, 0);
        let before = pool.pages_in_use();

        let mut b = pool.lookup_prefix(&prompt);
        // usable = 8 -> both full blocks adopted, nothing new allocated
        assert_eq!(b.len(), 8);
        assert_eq!(b.shared_toks(), 8);
        assert_eq!(pool.pages_in_use(), before);
        assert_eq!(pool.gauges().prefix_hit_toks, 8);
        // adopted rows read back identically
        let (k_a, _) = pool.row(&a.layers[1], 5);
        let k_a = k_a.to_vec();
        let (k_b, _) = pool.row(&b.layers[1], 5);
        assert_eq!(k_a, k_b.to_vec());

        pool.free_seq(&mut b);
        pool.free_seq(&mut a);
        // tree still holds both registered blocks (1 page per layer each)
        assert_eq!(pool.pages_in_use(), 2 * 2);
    }

    #[test]
    fn partial_adoption_cows_on_divergence() {
        let mut pool = KvPool::new(4, 8, 1);
        let donor: Vec<u16> = vec![1, 2, 3, 4, 9];
        let mut a = SeqKv::new(1);
        fill(&mut pool, &mut a, &donor, 0);

        // same first 3 tokens, diverges at position 3
        let prompt: Vec<u16> = vec![1, 2, 3, 7];
        let mut b = pool.lookup_prefix(&prompt);
        assert_eq!(b.len(), 3, "partial block adopted");
        assert_eq!(b.shared_toks(), 0, "partial rows are charged, not discounted");
        let shared_page = b.layers[0].pages[0];
        assert_eq!(shared_page, a.layers[0].pages[0]);

        // first append diverges -> copy-on-write to a private page
        let k: Vec<f32> = vec![7.0; 8];
        let lk = &mut b.layers[0];
        pool.append(lk, &k, &k);
        assert_ne!(b.layers[0].pages[0], shared_page);
        assert_eq!(pool.gauges().cow_copies, 1);
        // donor rows untouched
        let (dk, _) = pool.row(&a.layers[0], 3);
        assert_eq!(dk[0], 4.0);
        // our copied prefix + divergent row both read back
        let (bk0, _) = pool.row(&b.layers[0], 0);
        assert_eq!(bk0[0], 1.0);
        let (bk3, _) = pool.row(&b.layers[0], 3);
        assert_eq!(bk3[0], 7.0);
    }

    #[test]
    fn register_dedups_identical_blocks() {
        let mut pool = KvPool::new(4, 8, 1);
        let tokens: Vec<u16> = (0..5).collect();
        let mut a = SeqKv::new(1);
        fill(&mut pool, &mut a, &tokens, 0);
        // a fresh sequence computes the same block independently (as
        // happens when two identical prompts prefill in one batch)
        let mut b = SeqKv::new(1);
        fill(&mut pool, &mut b, &tokens, 0);
        // register converged b's full block onto a's page
        assert_eq!(b.layers[0].pages[0], a.layers[0].pages[0]);
        assert_eq!(pool.gauges().tree_blocks, 1);
    }

    #[test]
    fn page_cap_evicts_lru_tree_leaves() {
        let mut pool = KvPool::new(4, 8, 1);
        for i in 0..4u16 {
            let toks: Vec<u16> = (0..4).map(|t| t + i * 50).collect();
            let mut kv = SeqKv::new(1);
            fill(&mut pool, &mut kv, &toks, 0);
            pool.free_seq(&mut kv);
        }
        assert_eq!(pool.gauges().tree_blocks, 4);
        assert_eq!(pool.pages_in_use(), 4);
        pool.set_page_cap(2);
        assert_eq!(pool.pages_in_use(), 2);
        assert_eq!(pool.gauges().tree_blocks, 2);
        // oldest blocks went first: the newest prefix still hits
        let newest: Vec<u16> = (0..5).map(|t| t + 3 * 50).collect();
        assert_eq!(pool.probe_prefix(&newest), 4);
        let oldest: Vec<u16> = (0..5).collect();
        assert_eq!(pool.probe_prefix(&oldest), 0);
    }

    #[test]
    fn probe_matches_lookup_discount() {
        let mut pool = KvPool::new(4, 8, 1);
        let prompt: Vec<u16> = (0..13).collect();
        let mut a = SeqKv::new(1);
        fill(&mut pool, &mut a, &prompt, 0);
        for len in [1usize, 4, 5, 8, 9, 12, 13] {
            let p = &prompt[..len];
            let probed = pool.probe_prefix(p);
            let mut kv = pool.lookup_prefix(p);
            assert_eq!(probed, kv.shared_toks(), "prompt len {len}");
            assert!(probed <= len.saturating_sub(1));
            pool.free_seq(&mut kv);
        }
    }
}
