//! The MoE decoder model substrate (Mixtral / DeepSeek-VL2 analog).
//!
//! Decoder-only transformer where every FFN is an MoE layer: softmax
//! top-k routing over `E` experts plus always-on shared experts
//! (paper Eq. 1). This module owns the f32 weights and the full-sequence
//! forward used by training, calibration and perplexity evaluation; the
//! serving decode path (KV cache, batching, quantized/PJRT execution)
//! lives in `backend`/`coordinator`.

pub mod attention;
pub mod checkpoint;
pub mod dispatch;
pub mod expert;
pub mod gating;
pub mod kv;
pub mod model;
pub mod stats;

pub use dispatch::{dispatch_moe_layer, DispatchExecutor, DispatchHooks, DispatchOutcome};
pub use expert::Expert;
pub use gating::route;
pub use model::{ExpertId, ExpertProvider, ForwardOpts, MoeModel, Pruner};
pub use stats::RoutingStats;
