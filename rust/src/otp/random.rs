//! Random dynamic pruning baseline (Table 6's "PMQ+random" row): each
//! token independently prunes a uniformly-chosen number of tail experts
//! to hit a target expected pruning ratio — importance-blind, so it
//! degrades much faster than OTP at the same ratio.

use crate::moe::gating::Route;
use crate::moe::model::Pruner;
use crate::util::rng::Rng;

pub struct RandomPruner {
    /// Target expected fraction of activated experts to prune (0..1).
    pub ratio: f64,
    pub rng: Rng,
}

impl RandomPruner {
    pub fn new(ratio: f64, seed: u64) -> RandomPruner {
        RandomPruner { ratio, rng: Rng::new(seed) }
    }
}

impl Pruner for RandomPruner {
    fn keep(&mut self, _layer: usize, _x: &[f32], r: &Route) -> usize {
        let k = r.experts.len();
        // prune each non-top rank independently with p = ratio * k/(k-1)
        // so the expectation over all k slots is `ratio`
        let p = (self.ratio * k as f64 / (k - 1).max(1) as f64).min(1.0);
        let mut keep = 1;
        for _ in 1..k {
            if self.rng.f64() >= p {
                keep += 1;
            }
        }
        keep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::gating::Route;

    fn dummy_route(k: usize) -> Route {
        Route {
            experts: (0..k).collect(),
            weights: vec![1.0 / k as f32; k],
            scores: vec![1.0 / k as f32; k],
        }
    }

    #[test]
    fn hits_target_ratio_in_expectation() {
        let mut p = RandomPruner::new(1.0 / 6.0, 42);
        let r = dummy_route(6);
        let mut kept = 0u64;
        let n = 20_000;
        for _ in 0..n {
            kept += p.keep(0, &[], &r) as u64;
        }
        let ratio = 1.0 - kept as f64 / (n as f64 * 6.0);
        assert!((ratio - 1.0 / 6.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn always_keeps_at_least_one() {
        let mut p = RandomPruner::new(0.99, 43);
        let r = dummy_route(4);
        for _ in 0..100 {
            assert!(p.keep(0, &[], &r) >= 1);
        }
    }
}
