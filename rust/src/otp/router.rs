//! The learnable top-any router `DM(·)` (paper §3.4.1, Table 1).
//!
//! Two linear layers per MoE block: `FC1: H→k` over the token, then
//! `FC2: 2k→|C|` over `concat(relu(FC1(x)), w_topk)` — exactly the
//! parameter shapes of Table 1 (e.g. DeepSeek-VL2-S: 2048×6, 12×6, mask
//! 6×6). Training samples candidates via Gumbel-Softmax; inference takes
//! the argmax candidate (no noise) and prunes the tail experts.

use crate::moe::gating::Route;
use crate::moe::model::Pruner;
use crate::tensor::{softmax, Tensor2};
use crate::util::rng::Rng;

use super::mask::{candidate_masks, keep_of_candidate};

#[derive(Clone, Debug)]
pub struct OtpRouter {
    pub k: usize,
    pub fc1_w: Tensor2, // [H, k]
    pub fc1_b: Vec<f32>,
    pub fc2_w: Tensor2, // [2k, |C|=k]
    pub fc2_b: Vec<f32>,
}

/// Cached intermediates for the backward pass.
pub struct RouterFwd {
    pub h1: Vec<f32>,     // relu(fc1)
    pub concat: Vec<f32>, // [h1 ; gate_w]
    pub z: Vec<f32>,      // logits over candidates
    pub y: Vec<f32>,      // gumbel-softmax probabilities
    pub mask: Vec<f32>,   // y @ C_k (soft mask over ranks)
}

impl OtpRouter {
    pub fn new(d_model: usize, k: usize, rng: &mut Rng) -> OtpRouter {
        let s1 = 1.0 / (d_model as f32).sqrt();
        let s2 = 1.0 / (2.0 * k as f32).sqrt();
        OtpRouter {
            k,
            fc1_w: Tensor2::randn(d_model, k, rng, s1),
            fc1_b: vec![0.0; k],
            fc2_w: Tensor2::randn(2 * k, k, rng, s2),
            fc2_b: vec![0.0; k],
        }
    }

    pub fn n_params(&self) -> usize {
        self.fc1_w.data.len() + self.fc1_b.len() + self.fc2_w.data.len() + self.fc2_b.len()
    }

    /// Candidate logits for one token (inference: no noise).
    pub fn logits(&self, x: &[f32], gate_w: &[f32]) -> Vec<f32> {
        let k = self.k;
        let mut h1 = self.fc1_b.clone();
        for (r, &xr) in x.iter().enumerate() {
            if xr != 0.0 {
                crate::tensor::axpy(xr, self.fc1_w.row(r), &mut h1);
            }
        }
        for v in h1.iter_mut() {
            *v = v.max(0.0);
        }
        let mut z = self.fc2_b.clone();
        for (r, &c) in h1.iter().chain(gate_w.iter()).enumerate() {
            if c != 0.0 {
                crate::tensor::axpy(c, self.fc2_w.row(r), &mut z);
            }
        }
        debug_assert_eq!(z.len(), k);
        z
    }

    /// Training forward: Gumbel-Softmax sample at temperature `tau`
    /// (Eq. 13). Noise is passed in so runs replay.
    pub fn forward_gumbel(&self, x: &[f32], gate_w: &[f32], noise: &[f32], tau: f32) -> RouterFwd {
        let k = self.k;
        let mut h1 = self.fc1_b.clone();
        for (r, &xr) in x.iter().enumerate() {
            if xr != 0.0 {
                crate::tensor::axpy(xr, self.fc1_w.row(r), &mut h1);
            }
        }
        for v in h1.iter_mut() {
            *v = v.max(0.0);
        }
        let concat: Vec<f32> = h1.iter().chain(gate_w.iter()).cloned().collect();
        let mut z = self.fc2_b.clone();
        for (r, &c) in concat.iter().enumerate() {
            if c != 0.0 {
                crate::tensor::axpy(c, self.fc2_w.row(r), &mut z);
            }
        }
        let mut y: Vec<f32> = z.iter().zip(noise).map(|(&zi, &n)| (zi + n) / tau).collect();
        softmax(&mut y);
        let cand = candidate_masks(k);
        let mut mask = vec![0.0f32; k];
        for (c, yc) in y.iter().enumerate() {
            for r in 0..k {
                mask[r] += yc * cand[c][r];
            }
        }
        RouterFwd { h1, concat, z, y, mask }
    }

    /// Inference decision: keep count from the argmax candidate.
    pub fn keep(&self, x: &[f32], gate_w: &[f32]) -> usize {
        let z = self.logits(x, gate_w);
        let c = z
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        keep_of_candidate(self.k, c)
    }
}

/// Per-layer routers acting as a [`Pruner`] in the shared forward.
pub struct OtpPruner {
    pub routers: Vec<OtpRouter>,
}

impl Pruner for OtpPruner {
    fn keep(&mut self, layer: usize, x: &[f32], route: &Route) -> usize {
        self.routers[layer].keep(x, &route.weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_parameter_shapes() {
        let mut rng = Rng::new(1);
        // DeepSeek-VL2-S analog: H=2048 in the paper (FC1 2048×6, FC2
        // 12×6, mask 6×6); we check the shape *rule*, paper Table 1.
        let r = OtpRouter::new(2048, 6, &mut rng);
        assert_eq!((r.fc1_w.rows, r.fc1_w.cols), (2048, 6));
        assert_eq!((r.fc2_w.rows, r.fc2_w.cols), (12, 6));
        assert_eq!(candidate_masks(6).len(), 6);
        // Mixtral analog: FC1 4096×2, FC2 4×2, mask 2×2
        let r2 = OtpRouter::new(4096, 2, &mut rng);
        assert_eq!((r2.fc1_w.rows, r2.fc1_w.cols), (4096, 2));
        assert_eq!((r2.fc2_w.rows, r2.fc2_w.cols), (4, 2));
    }

    #[test]
    fn gumbel_forward_consistent_with_logits_at_zero_noise() {
        let mut rng = Rng::new(2);
        let r = OtpRouter::new(32, 4, &mut rng);
        let x: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let gw = vec![0.5, 0.3, 0.15, 0.05];
        let z = r.logits(&x, &gw);
        let f = r.forward_gumbel(&x, &gw, &[0.0; 4], 1.0);
        for (a, b) in z.iter().zip(&f.z) {
            assert!((a - b).abs() < 1e-6);
        }
        assert!((f.y.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        // soft mask monotone across ranks
        for w in f.mask.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
    }

    #[test]
    fn keep_in_valid_range() {
        let mut rng = Rng::new(3);
        let r = OtpRouter::new(16, 6, &mut rng);
        for _ in 0..50 {
            let x: Vec<f32> = (0..16).map(|_| rng.normal()).collect();
            let gw: Vec<f32> = (0..6).map(|_| rng.f32()).collect();
            let k = r.keep(&x, &gw);
            assert!((1..=6).contains(&k));
        }
    }

    #[test]
    fn low_tau_sharpens_y() {
        let mut rng = Rng::new(4);
        let r = OtpRouter::new(16, 4, &mut rng);
        let x: Vec<f32> = (0..16).map(|_| rng.normal() * 3.0).collect();
        let gw = vec![0.4, 0.3, 0.2, 0.1];
        let hi = r.forward_gumbel(&x, &gw, &[0.0; 4], 4.0);
        let lo = r.forward_gumbel(&x, &gw, &[0.0; 4], 0.05);
        let peak = |y: &[f32]| y.iter().cloned().fold(0.0f32, f32::max);
        assert!(peak(&lo.y) > peak(&hi.y));
        assert!(peak(&lo.y) > 0.95);
    }
}
