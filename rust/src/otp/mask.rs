//! The nested top-any candidate set C_k (paper Eq. 10).
//!
//! Candidate `c` keeps the first `k - c` rank-sorted experts; `|C| = k`,
//! so candidate 0 prunes nothing and candidate k-1 keeps only the top
//! expert. Must match `python/compile/kernels/ref.py::candidate_masks`.

/// Row-major `[k, k]` candidate matrix: `C[c][r] = 1` iff rank `r` is
/// kept by candidate `c`.
pub fn candidate_masks(k: usize) -> Vec<Vec<f32>> {
    (0..k)
        .map(|c| (0..k).map(|r| if r < k - c { 1.0 } else { 0.0 }).collect())
        .collect()
}

/// Number of experts candidate `c` keeps.
pub fn keep_of_candidate(k: usize, c: usize) -> usize {
    k - c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_eq10_for_k6() {
        let c = candidate_masks(6);
        assert_eq!(c[0], vec![1.0; 6]);
        assert_eq!(c[1], vec![1.0, 1.0, 1.0, 1.0, 1.0, 0.0]);
        assert_eq!(c[5], vec![1.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn nested_and_keep_counts() {
        for k in 1..=8 {
            let c = candidate_masks(k);
            assert_eq!(c.len(), k);
            for (ci, row) in c.iter().enumerate() {
                let kept: usize = row.iter().map(|&v| v as usize).sum();
                assert_eq!(kept, keep_of_candidate(k, ci));
                // masks are monotone non-increasing across ranks
                for w in row.windows(2) {
                    assert!(w[0] >= w[1]);
                }
            }
        }
    }
}
