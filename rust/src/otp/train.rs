//! OTP training (paper §3.4.2, Eq. 14).
//!
//! Loss per token and layer: `‖Σ_r m_r w_r F_r(x) − Σ_r w_r F_r(x)‖² / H
//! + λ · mean(m)` — a layer-local distillation of the unmasked quantized
//! model plus the ℓ1 sparsity *pressure* on the soft mask. (The paper
//! distills final logits; layer-local distillation is the telescoped
//! surrogate — each layer's masked output is pushed toward the unmasked
//! one, which bounds the logit drift. Documented in DESIGN.md §6.)
//!
//! The mask samples through Gumbel-Softmax (temperature annealed
//! `tau_start → tau_end`), so gradients reach FC1/FC2 through the
//! candidate probabilities exactly as in Eq. 13. Expert outputs are
//! precomputed per calibration token — routers never change routing, so
//! the distillation targets are static and training is fast.

use crate::config::OtpConfig;
use crate::moe::model::{ExpertProvider, ForwardOpts};
use crate::quant::qmodel::QuantModel;
use crate::util::rng::Rng;

use super::mask::candidate_masks;
use super::router::OtpRouter;

/// One cached training token for one layer.
struct TokenSample {
    x: Vec<f32>,
    /// Rank-sorted routing weights (len k).
    gate_w: Vec<f32>,
    /// Per rank: w_r * F_r(x) (quantized expert output, pre-weighted).
    weighted_outs: Vec<Vec<f32>>,
    /// Σ_r w_r F_r(x) — the unmasked target.
    full: Vec<f32>,
}

/// Training curve data (Fig. 13): mask ratio & loss per logged step.
pub struct OtpTrainReport {
    pub routers: Vec<OtpRouter>,
    /// (step, mean mask ratio pruned, distill loss) samples.
    pub curve: Vec<(usize, f64, f64)>,
}

fn collect_samples(
    q: &QuantModel,
    seqs: &[Vec<u16>],
    max_per_layer: usize,
) -> Vec<Vec<TokenSample>> {
    let cfg = &q.model.cfg;
    let mut captured: Vec<Vec<Vec<f32>>> = vec![Vec::new(); cfg.n_layers];
    for s in seqs {
        let mut opts = ForwardOpts {
            provider: Some(q),
            capture_moe_inputs: Some(&mut captured),
            ..Default::default()
        };
        q.model.forward_opts(s, &mut opts);
    }
    captured
        .into_iter()
        .enumerate()
        .map(|(l, mut xs)| {
            xs.truncate(max_per_layer);
            xs.into_iter()
                .map(|x| {
                    let r = crate::moe::gating::route(&x, &q.model.blocks[l].gate, cfg.top_k);
                    // batch the residency I/O for the routed set (paged
                    // stores fault once here, not per ffn_row_acc below)
                    q.ensure_resident(l, &r.experts)
                        .expect("expert residency failed during OTP sampling");
                    let mut weighted_outs = Vec::with_capacity(cfg.top_k);
                    let mut full = vec![0.0f32; cfg.d_model];
                    for (rank, &e) in r.experts.iter().enumerate() {
                        let mut out = vec![0.0f32; cfg.d_model];
                        q.expert(l, e).ffn_row_acc(&x, r.weights[rank], &mut out);
                        for (f, &o) in full.iter_mut().zip(&out) {
                            *f += o;
                        }
                        weighted_outs.push(out);
                    }
                    TokenSample { x, gate_w: r.weights, weighted_outs, full }
                })
                .collect()
        })
        .collect()
}

/// Adam state for one router.
struct RouterAdam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl RouterAdam {
    fn new(n: usize) -> RouterAdam {
        RouterAdam { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    fn step(&mut self, params: &mut [&mut f32], grads: &[f32], lr: f32) {
        self.t += 1;
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for (i, p) in params.iter_mut().enumerate() {
            let g = grads[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            **p -= lr * (self.m[i] / bc1) / ((self.v[i] / bc2).sqrt() + eps);
        }
    }
}

/// Train one router per MoE layer of the quantized model.
pub fn train_otp(q: &QuantModel, seqs: &[Vec<u16>], oc: &OtpConfig, seed: u64) -> OtpTrainReport {
    let cfg = &q.model.cfg;
    let k = cfg.top_k;
    let h = cfg.d_model;
    let mut rng = Rng::new(seed);
    let samples = collect_samples(q, seqs, 1024);
    let mut routers: Vec<OtpRouter> =
        (0..cfg.n_layers).map(|_| OtpRouter::new(h, k, &mut rng)).collect();
    let mut adams: Vec<RouterAdam> =
        routers.iter().map(|r| RouterAdam::new(r.n_params())).collect();
    let cand = candidate_masks(k);
    let mut curve = Vec::new();

    for step in 0..oc.steps {
        let frac = step as f32 / oc.steps.max(1) as f32;
        let tau = oc.tau_start + (oc.tau_end - oc.tau_start) * frac;
        let mut step_loss = 0.0f64;
        let mut step_mask = 0.0f64;
        let mut n_tok = 0usize;
        for (l, router) in routers.iter_mut().enumerate() {
            let pool = &samples[l];
            if pool.is_empty() {
                continue;
            }
            // gradient accumulators (canonical order: fc1_w, fc1_b, fc2_w, fc2_b)
            let n1 = router.fc1_w.data.len();
            let n1b = router.fc1_b.len();
            let n2 = router.fc2_w.data.len();
            let mut grads = vec![0.0f32; router.n_params()];
            for _ in 0..oc.batch_tokens {
                let s = &pool[rng.below(pool.len())];
                let noise: Vec<f32> = (0..k).map(|_| rng.gumbel()).collect();
                let f = router.forward_gumbel(&s.x, &s.gate_w, &noise, tau);
                // masked output & distill loss
                let mut masked = vec![0.0f32; h];
                for (r, out) in s.weighted_outs.iter().enumerate() {
                    let m = f.mask[r];
                    if m != 0.0 {
                        crate::tensor::axpy(m, out, &mut masked);
                    }
                }
                let mut dmask = vec![0.0f32; k];
                let mut dist = 0.0f32;
                for r in 0..k {
                    let mut dot = 0.0f32;
                    for d in 0..h {
                        let diff = masked[d] - s.full[d];
                        if r == 0 {
                            dist += diff * diff;
                        }
                        dot += diff * s.weighted_outs[r][d];
                    }
                    dmask[r] = 2.0 * dot / h as f32 + oc.lambda / k as f32;
                }
                dist /= h as f32;
                step_loss += dist as f64;
                step_mask += f.mask.iter().map(|&m| 1.0 - m as f64).sum::<f64>() / k as f64;
                n_tok += 1;
                // mask = y @ C  ⇒ dy_c = Σ_r dmask_r C[c][r]
                let mut dy = vec![0.0f32; k];
                for c in 0..k {
                    for r in 0..k {
                        dy[c] += dmask[r] * cand[c][r];
                    }
                }
                // softmax((z+n)/tau) backward
                let dot: f32 = dy.iter().zip(&f.y).map(|(a, b)| a * b).sum();
                let dz: Vec<f32> =
                    (0..k).map(|c| f.y[c] * (dy[c] - dot) / tau).collect();
                // fc2 backward
                for (r, &cv) in f.concat.iter().enumerate() {
                    for c in 0..k {
                        grads[n1 + n1b + r * k + c] += cv * dz[c];
                    }
                }
                for c in 0..k {
                    grads[n1 + n1b + n2 + c] += dz[c];
                }
                // into h1 (first k rows of fc2) through relu
                for r in 0..k {
                    if f.h1[r] > 0.0 {
                        let mut dh = 0.0f32;
                        for c in 0..k {
                            dh += router.fc2_w.at(r, c) * dz[c];
                        }
                        // fc1 backward
                        for (xi, &xv) in s.x.iter().enumerate() {
                            grads[xi * k + r] += xv * dh;
                        }
                        grads[n1 + r] += dh;
                    }
                }
            }
            let inv = 1.0 / oc.batch_tokens as f32;
            for g in grads.iter_mut() {
                *g *= inv;
            }
            // apply Adam
            let mut params: Vec<&mut f32> = Vec::with_capacity(router.n_params());
            params.extend(router.fc1_w.data.iter_mut());
            params.extend(router.fc1_b.iter_mut());
            params.extend(router.fc2_w.data.iter_mut());
            params.extend(router.fc2_b.iter_mut());
            adams[l].step(&mut params, &grads, oc.lr);
        }
        if step % 10 == 0 || step + 1 == oc.steps {
            curve.push((
                step,
                step_mask / n_tok.max(1) as f64,
                step_loss / n_tok.max(1) as f64,
            ));
        }
    }
    OtpTrainReport { routers, curve }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, PmqConfig};
    use crate::moe::MoeModel;
    use crate::quant::qmodel::QuantMethod;

    fn quick_qmodel() -> QuantModel {
        let cfg = ModelConfig {
            name: "otp-test".into(),
            family: "mixtral".into(),
            vocab_size: 512,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            n_experts: 6,
            top_k: 3,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let base = MoeModel::new(&cfg, 17);
        QuantModel::quantize(
            &base,
            &vec![vec![2u8; 6]; 2],
            &PmqConfig::default(),
            &QuantMethod::Rtn,
        )
    }

    #[test]
    fn training_learns_nonzero_pruning_with_low_loss() {
        let q = quick_qmodel();
        let corpus = crate::data::Corpus::new(crate::data::CorpusKind::General, 6);
        let mut rng = Rng::new(7);
        let seqs = corpus.batch(4, 32, &mut rng);
        let oc = OtpConfig { steps: 80, batch_tokens: 32, lambda: 1.0, ..Default::default() };
        let rep = train_otp(&q, &seqs, &oc, 99);
        assert_eq!(rep.routers.len(), 2);
        let (_, final_mask, _) = *rep.curve.last().unwrap();
        // λ=1 should push some pruning (paper Fig. 13: ~30%) but not all
        assert!(final_mask > 0.02 && final_mask < 0.9, "mask ratio {final_mask}");
    }

    #[test]
    fn higher_lambda_prunes_more() {
        let q = quick_qmodel();
        let corpus = crate::data::Corpus::new(crate::data::CorpusKind::General, 6);
        let mut rng = Rng::new(8);
        let seqs = corpus.batch(4, 32, &mut rng);
        let run = |lambda: f32| {
            let oc = OtpConfig { steps: 60, batch_tokens: 32, lambda, ..Default::default() };
            let rep = train_otp(&q, &seqs, &oc, 100);
            rep.curve.last().unwrap().1
        };
        let lo = run(0.25);
        let hi = run(4.0);
        assert!(hi > lo, "λ=4 mask {hi} not > λ=0.25 mask {lo}");
    }
}
