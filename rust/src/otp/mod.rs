//! OTP — Online Top-any Pruning (paper §3.4).
//!
//! A tiny learnable router per MoE layer picks, per token, one of the
//! nested candidate masks `C_k` over the rank-sorted top-k experts
//! (Eq. 10). Training samples masks through Gumbel-Softmax (Eq. 12–13)
//! against a distillation + λ·ℓ1-sparsity objective (Eq. 14); inference
//! takes the argmax candidate and skips the pruned experts entirely.
//!
//! Baselines: [`odp`] (the rule-based top-k skipping of the conference
//! version / ref. \[8\], Eq. 5) and [`random`].

pub mod mask;
pub mod odp;
pub mod random;
pub mod router;
pub mod train;

pub use mask::candidate_masks;
pub use odp::OdpPruner;
pub use random::RandomPruner;
pub use router::{OtpPruner, OtpRouter};
pub use train::{train_otp, OtpTrainReport};
