//! ODP — the rule-based dynamic pruning baseline (paper Eq. 5, the
//! conference version \[1\] / Lu et al. \[8\]).
//!
//! For k = 2: skip the second expert when `w1/w0 < μ`, with μ the median
//! of that ratio on calibration data, per layer. For k > 2 we use the
//! natural generalization the paper alludes to (and shows is brittle):
//! keep rank r while `w_r / w_0 ≥ μ` — a fixed per-layer threshold that
//! cannot adapt per token, which is exactly the weakness OTP fixes.

use crate::moe::gating::Route;
use crate::moe::model::{ForwardOpts, MoeModel, Pruner};

pub struct OdpPruner {
    /// Per-layer threshold μ.
    pub mu: Vec<f32>,
}

impl OdpPruner {
    /// Calibrate μ per layer = median of `w1/w0` over calibration tokens
    /// (paper: "set at the median value of w1/w0 derived from
    /// calibration data").
    pub fn calibrate(model: &MoeModel, seqs: &[Vec<u16>]) -> OdpPruner {
        struct Collect {
            ratios: Vec<Vec<f32>>,
        }
        impl Pruner for Collect {
            fn keep(&mut self, layer: usize, _x: &[f32], r: &Route) -> usize {
                if r.weights.len() >= 2 && r.weights[0] > 0.0 {
                    self.ratios[layer].push(r.weights[1] / r.weights[0]);
                }
                r.experts.len() // keep everything while calibrating
            }
        }
        let mut c = Collect { ratios: vec![Vec::new(); model.cfg.n_layers] };
        for s in seqs {
            let mut opts = ForwardOpts { pruner: Some(&mut c), ..Default::default() };
            model.forward_opts(s, &mut opts);
        }
        let mu = c
            .ratios
            .into_iter()
            .map(|mut rs| {
                if rs.is_empty() {
                    return 0.5;
                }
                rs.sort_by(|a, b| a.partial_cmp(b).unwrap());
                rs[rs.len() / 2]
            })
            .collect();
        OdpPruner { mu }
    }
}

impl Pruner for OdpPruner {
    fn keep(&mut self, layer: usize, _x: &[f32], r: &Route) -> usize {
        let mu = self.mu[layer];
        let w0 = r.weights[0].max(1e-9);
        let mut keep = 1;
        for w in r.weights.iter().skip(1) {
            if w / w0 >= mu {
                keep += 1;
            } else {
                break; // weights are rank-sorted; the tail is below too
            }
        }
        keep
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::data::{Corpus, CorpusKind};
    use crate::util::rng::Rng;

    #[test]
    fn calibrated_odp_prunes_roughly_half_of_rank2() {
        let cfg = ModelConfig {
            name: "odp-test".into(),
            family: "mixtral".into(),
            vocab_size: 512,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let model = MoeModel::new(&cfg, 16);
        let corpus = Corpus::new(CorpusKind::General, 3);
        let mut rng = Rng::new(5);
        let calib = corpus.batch(4, 32, &mut rng);
        let mut odp = OdpPruner::calibrate(&model, &calib);
        // μ is the median ⇒ about half the tokens prune the 2nd expert
        let eval = corpus.batch(4, 32, &mut rng);
        let mut counter = (0u64, 0u64);
        for s in &eval {
            let mut opts = ForwardOpts {
                pruner: Some(&mut odp),
                pruning_counter: Some(&mut counter),
                ..Default::default()
            };
            model.forward_opts(s, &mut opts);
        }
        let ratio = 1.0 - counter.0 as f64 / counter.1 as f64;
        assert!(
            ratio > 0.1 && ratio < 0.4,
            "pruning ratio {ratio} not near the ~25% median rule"
        );
    }
}
