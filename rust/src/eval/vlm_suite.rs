//! The 6-task multimodal suite (Table 4 analog): caption-matching and
//! patch-reasoning items over the M4-analog corpus. The MME-analog
//! reports on the paper's ~0–2000 scale; the suite average (like the
//! paper) excludes it.

use crate::data::{vocab::*, Corpus, CorpusKind};
use crate::moe::model::MoeModel;
use crate::util::rng::Rng;

use super::mc::{score_items, EvalOpts, McItem};

pub const TASKS: [&str; 6] = ["mmbench~", "mmstar~", "mme~", "mmmu~", "ai2d~", "ocrbench~"];

/// Build all 6 tasks (`n` items each).
pub fn build(n: usize, seed: u64) -> Vec<(String, Vec<McItem>)> {
    let corpus = Corpus::new(CorpusKind::Multimodal, 0xDA7A);
    let mut rng = Rng::new(seed ^ 0x77AA);
    TASKS
        .iter()
        .map(|&name| {
            let items: Vec<McItem> = (0..n)
                .map(|_| match name {
                    // image → which caption (2 / 4 choices, diff hardness)
                    "mmbench~" => caption_item(&corpus, &mut rng, 2, 10),
                    "mmstar~" => caption_item(&corpus, &mut rng, 4, 8),
                    "mme~" => caption_item(&corpus, &mut rng, 2, 6),
                    "mmmu~" => caption_item(&corpus, &mut rng, 4, 6),
                    // caption → which image (inverse direction)
                    "ai2d~" => image_item(&corpus, &mut rng, 4),
                    // digits embedded after IMG span must be read back
                    "ocrbench~" => ocr_item(&corpus, &mut rng),
                    _ => unreachable!(),
                })
                .collect();
            (name.to_string(), items)
        })
        .collect()
}

/// `[IMG] patches [\IMG]` context; choices are captions, one from the
/// image's class.
fn caption_item(corpus: &Corpus, rng: &mut Rng, n_choices: usize, cap_len: usize) -> McItem {
    let class = rng.below(corpus.n_classes());
    let mut context = vec![BOS, IMG_START];
    context.extend(corpus.class_patches(class, 10, rng));
    context.push(IMG_END);
    let mut choices = vec![corpus.class_caption(class, cap_len, rng)];
    while choices.len() < n_choices {
        let other = (class + 1 + rng.below(corpus.n_classes() - 1)) % corpus.n_classes();
        choices.push(corpus.class_caption(other, cap_len, rng));
    }
    let correct = rng.below(n_choices);
    choices.swap(0, correct);
    McItem { context, choices, correct }
}

/// Caption context; choices are image spans (patch sequences).
fn image_item(corpus: &Corpus, rng: &mut Rng, n_choices: usize) -> McItem {
    let class = rng.below(corpus.n_classes());
    let mut context = vec![BOS];
    context.extend(corpus.class_caption(class, 10, rng));
    context.push(SEP);
    let make_img = |cl: usize, rng: &mut Rng| {
        let mut v = vec![IMG_START];
        v.extend(corpus.class_patches(cl, 8, rng));
        v.push(IMG_END);
        v
    };
    let mut choices = vec![make_img(class, rng)];
    while choices.len() < n_choices {
        let other = (class + 1 + rng.below(corpus.n_classes() - 1)) % corpus.n_classes();
        choices.push(make_img(other, rng));
    }
    let correct = rng.below(n_choices);
    choices.swap(0, correct);
    McItem { context, choices, correct }
}

/// OCR-analog: the needle/copy pattern inside a multimodal context.
fn ocr_item(corpus: &Corpus, rng: &mut Rng) -> McItem {
    let class = rng.below(corpus.n_classes());
    let digits: Vec<u16> = (0..3).map(|_| DIGIT_BASE + rng.below(10) as u16).collect();
    let mut context = vec![BOS, IMG_START];
    context.extend(corpus.class_patches(class, 8, rng));
    context.push(IMG_END);
    context.push(NEEDLE);
    context.extend(&digits);
    context.push(QUERY);
    let mut alt = digits.clone();
    let i = rng.below(3);
    alt[i] = DIGIT_BASE + ((alt[i] - DIGIT_BASE + 1 + rng.below(9) as u16) % 10);
    let correct = rng.below(2);
    let choices = if correct == 0 { vec![digits, alt] } else { vec![alt, digits] };
    McItem { context, choices, correct }
}

/// Table 4 row: per-task scores with the MME-analog on its 0–2000 scale,
/// plus the average over the other five (the paper's "Avg.%" convention).
pub struct VlmRow {
    pub scores: Vec<(String, f64)>,
    pub avg: f64,
}

pub fn score_vlm(model: &MoeModel, opts: &mut EvalOpts, n: usize, seed: u64) -> VlmRow {
    let tasks = build(n, seed);
    let mut scores = Vec::new();
    let mut avg_sum = 0.0;
    let mut avg_n = 0usize;
    for (name, items) in &tasks {
        let acc = 100.0 * score_items(model, opts, items);
        if name == "mme~" {
            // MME reports a ~0–2000 aggregate (2 subtasks × 1000)
            scores.push((name.clone(), acc * 20.0));
        } else {
            scores.push((name.clone(), acc));
            avg_sum += acc;
            avg_n += 1;
        }
    }
    VlmRow { scores, avg: avg_sum / avg_n.max(1) as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_shape_and_determinism() {
        let a = build(4, 3);
        assert_eq!(a.len(), 6);
        let b = build(4, 3);
        for ((n1, i1), (_n2, i2)) in a.iter().zip(&b) {
            assert_eq!(i1.len(), 4, "{n1}");
            for (x, y) in i1.iter().zip(i2) {
                assert_eq!(x.context, y.context);
            }
        }
    }

    #[test]
    fn items_are_multimodal() {
        let suite = build(4, 5);
        for (name, items) in &suite {
            if name == "ai2d~" {
                continue; // images are in the choices there
            }
            for it in items {
                assert!(it.context.iter().any(|&t| is_patch(t)), "{name}: no patches");
            }
        }
    }

    #[test]
    fn mme_scale() {
        use crate::config::ModelConfig;
        let cfg = ModelConfig {
            name: "vlm-test".into(),
            family: "deepseek-vl2".into(),
            vocab_size: 512,
            d_model: 24,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 1,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 2,
            buckets: vec![4],
        };
        let m = MoeModel::new(&cfg, 90);
        let row = score_vlm(&m, &mut EvalOpts::default(), 6, 1);
        let mme = row.scores.iter().find(|s| s.0 == "mme~").unwrap().1;
        assert!((0.0..=2000.0).contains(&mme));
        assert!((0.0..=100.0).contains(&row.avg));
    }
}
