//! Table 7's challenging benchmarks: GSM8K-analog (multi-step
//! arithmetic, exact-match generation), HumanEval-analog (pattern
//! synthesis, pass@10 sampling) and NIAH-analog (long-context needle
//! retrieval). These require *generation*, not choice-scoring, so they
//! degrade first under compression — the paper's Table 7 observation.

use crate::backend::ExpertBackend;
use crate::coordinator::engine::{DecodeEngine, EngineModel};
use crate::data::vocab::*;
use crate::data::{Corpus, CorpusKind};
use crate::moe::model::Pruner;
use crate::util::rng::Rng;

pub struct HardScores {
    pub gsm: f64,
    pub humaneval_p10: f64,
    pub niah: f64,
}

struct GenItem {
    prompt: Vec<u16>,
    answer: Vec<u16>,
}

/// GSM-analog: `a+b=c SEP c+d=` → the model must produce `e = c+d`,
/// having to carry `c` across the step boundary.
fn gsm_items(n: usize, seed: u64) -> Vec<GenItem> {
    let mut rng = Rng::new(seed ^ 0x65E1);
    (0..n)
        .map(|_| {
            let a = rng.below(50) as u32;
            let b = rng.below(50) as u32;
            let c = a + b;
            let d = rng.below(50) as u32;
            let mut prompt = vec![BOS];
            encode_number(a, &mut prompt);
            prompt.push(OP_PLUS);
            encode_number(b, &mut prompt);
            prompt.push(EQUALS);
            encode_number(c, &mut prompt);
            prompt.push(SEP);
            encode_number(c, &mut prompt);
            prompt.push(OP_PLUS);
            encode_number(d, &mut prompt);
            prompt.push(EQUALS);
            let mut answer = Vec::new();
            encode_number(c + d, &mut answer);
            GenItem { prompt, answer }
        })
        .collect()
}

/// NIAH-analog: needle digits buried in a long filler context, retrieved
/// at the QUERY marker.
fn niah_items(n: usize, ctx_len: usize, seed: u64) -> Vec<GenItem> {
    let corpus = Corpus::new(CorpusKind::General, 0xDA7A);
    let mut rng = Rng::new(seed ^ 0x41A7);
    (0..n)
        .map(|_| {
            let digits: Vec<u16> = (0..3).map(|_| DIGIT_BASE + rng.below(10) as u16).collect();
            let mut prompt = vec![BOS, NEEDLE];
            prompt.extend(&digits);
            // long filler from the training distribution
            let filler = corpus.sample(ctx_len, &mut rng);
            prompt.extend(&filler[1..]); // skip its BOS
            prompt.push(QUERY);
            GenItem { prompt, answer: digits }
        })
        .collect()
}

/// Token-level answer accuracy (%): mean fraction of answer tokens the
/// greedy generation gets right. The paper reports exact match; on this
/// testbed's ~3.5M-parameter models full-sequence exact match floors at
/// 0 for *fp16 as well* (generation capability, not compression, is the
/// limit), so degradation-under-compression — the quantity Table 7
/// tests — is measured at token granularity instead.
fn exact_match_score(
    engine: &mut DecodeEngine,
    items: &[GenItem],
) -> f64 {
    let mut credit = 0.0f64;
    for it in items {
        let out = engine.generate(&it.prompt, it.answer.len()).unwrap_or_default();
        let got = &out[it.prompt.len().min(out.len())..];
        let hit = it
            .answer
            .iter()
            .zip(got)
            .filter(|(a, b)| a == b)
            .count();
        credit += hit as f64 / it.answer.len().max(1) as f64;
    }
    100.0 * credit / items.len().max(1) as f64
}

/// HumanEval-analog pass@10: given a repeating token pattern
/// `x y z x y z x y`, any of 10 temperature samples must complete the
/// next `m` tokens exactly.
fn humaneval_p10(engine: &mut DecodeEngine, n: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed ^ 0x4E1);
    let mut ok = 0usize;
    for _ in 0..n {
        let period = 2 + rng.below(2);
        let motif: Vec<u16> =
            (0..period).map(|_| TEXT_BASE + rng.below(N_TEXT) as u16).collect();
        let reps = 4;
        let mut prompt = vec![BOS];
        for _ in 0..reps {
            prompt.extend(&motif);
        }
        let m = period; // complete one more period
        let answer = motif.clone();
        let mut passed = false;
        for s in 0..10 {
            let out = {
                // temperature sampling via SeqState.sample
                let model = engine.em.model();
                let n_layers = model.cfg.n_layers;
                let mut seq = crate::coordinator::engine::SeqState::new(
                    s,
                    prompt.clone(),
                    m,
                    n_layers,
                );
                seq.sample = Some((0.7, seed + s));
                while !seq.done() {
                    let mut batch = [&mut seq];
                    if engine.step(&mut batch).is_err() {
                        break;
                    }
                }
                // release this attempt's pages back to the shared pool
                engine.kv_pool().lock().unwrap().free_seq(&mut seq.kv);
                seq.tokens
            };
            if out.len() >= prompt.len() + m && out[prompt.len()..prompt.len() + m] == answer[..m]
            {
                passed = true;
                break;
            }
        }
        if passed {
            ok += 1;
        }
    }
    100.0 * ok as f64 / n.max(1) as f64
}

/// Run all three hard tasks through a decode engine.
pub fn score_hard(
    em: EngineModel,
    backend: &dyn ExpertBackend,
    pruner: Option<Box<dyn Pruner + '_>>,
    n: usize,
    ctx_len: usize,
    seed: u64,
) -> HardScores {
    let mut engine = DecodeEngine::new(em, backend, pruner);
    let gsm = exact_match_score(&mut engine, &gsm_items(n, seed));
    let humaneval_p10 = humaneval_p10(&mut engine, n, seed);
    let niah = exact_match_score(&mut engine, &niah_items(n, ctx_len, seed));
    HardScores { gsm, humaneval_p10, niah }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::config::ModelConfig;
    use crate::moe::MoeModel;

    #[test]
    fn items_are_wellformed() {
        for it in gsm_items(10, 1) {
            assert!(it.prompt.len() > 6);
            assert!(!it.answer.is_empty());
            assert!(it.answer.iter().all(|&t| (DIGIT_BASE..DIGIT_BASE + 10).contains(&t)));
        }
        for it in niah_items(5, 40, 2) {
            assert_eq!(it.prompt[1], NEEDLE);
            assert_eq!(*it.prompt.last().unwrap(), QUERY);
            assert!(it.prompt.len() > 40);
        }
    }

    #[test]
    fn scores_in_range_on_random_model() {
        let cfg = ModelConfig {
            name: "hard-test".into(),
            family: "mixtral".into(),
            vocab_size: 512,
            d_model: 24,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            n_experts: 2,
            top_k: 1,
            n_shared_experts: 0,
            max_seq_len: 128,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let m = MoeModel::new(&cfg, 91);
        let be = NativeBackend::fp(&m);
        let s = score_hard(EngineModel::Fp(&m), &be, None, 4, 24, 3);
        for v in [s.gsm, s.humaneval_p10, s.niah] {
            assert!((0.0..=100.0).contains(&v));
        }
    }
}
