//! Generic multiple-choice evaluation: pick the continuation with the
//! highest length-normalized logprob given the context.

use crate::moe::model::{ExpertProvider, ForwardOpts, MoeModel, Pruner};
use crate::tensor::softmax;

/// One multiple-choice item.
#[derive(Clone, Debug)]
pub struct McItem {
    pub context: Vec<u16>,
    pub choices: Vec<Vec<u16>>,
    pub correct: usize,
}

/// Evaluation-time model options (quantized provider and/or pruner),
/// plus a pruning-ratio counter shared across the whole suite run.
#[derive(Default)]
pub struct EvalOpts<'a> {
    pub provider: Option<&'a dyn ExpertProvider>,
    pub pruner: Option<&'a mut dyn Pruner>,
    pub pruning_counter: Option<&'a mut (u64, u64)>,
}

impl<'a> EvalOpts<'a> {
    fn fwd<'b>(&'b mut self) -> ForwardOpts<'b>
    where
        'a: 'b,
    {
        ForwardOpts {
            provider: self.provider,
            pruner: self.pruner.as_deref_mut().map(|p| p as &mut dyn Pruner),
            pruning_counter: self.pruning_counter.as_deref_mut(),
            ..Default::default()
        }
    }
}

/// Mean logprob of `choice` tokens appended to `context`.
pub fn choice_logprob(
    model: &MoeModel,
    opts: &mut EvalOpts,
    context: &[u16],
    choice: &[u16],
) -> f64 {
    let mut seq = context.to_vec();
    seq.extend_from_slice(choice);
    let mut fwd = opts.fwd();
    let logits = model.forward_opts(&seq, &mut fwd);
    let mut total = 0.0f64;
    for (ci, &tok) in choice.iter().enumerate() {
        let pos = context.len() + ci - 1; // logits at pos predict pos+1
        let mut row = logits.row(pos).to_vec();
        softmax(&mut row);
        total += (row[tok as usize].max(1e-30) as f64).ln();
    }
    total / choice.len() as f64
}

/// Accuracy over a set of items (fraction where the correct choice wins).
pub fn score_items(model: &MoeModel, opts: &mut EvalOpts, items: &[McItem]) -> f64 {
    let mut correct = 0usize;
    for item in items {
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (ci, choice) in item.choices.iter().enumerate() {
            let lp = choice_logprob(model, opts, &item.context, choice);
            if lp > best.0 {
                best = (lp, ci);
            }
        }
        if best.1 == item.correct {
            correct += 1;
        }
    }
    correct as f64 / items.len().max(1) as f64
}

/// Score a named set of tasks; returns (name, accuracy %) rows plus the
/// average — the shape of the paper's Table 2/4 rows.
pub fn score_suite(
    model: &MoeModel,
    opts: &mut EvalOpts,
    tasks: &[(String, Vec<McItem>)],
) -> (Vec<(String, f64)>, f64) {
    let mut rows = Vec::new();
    for (name, items) in tasks {
        rows.push((name.clone(), 100.0 * score_items(model, opts, items)));
    }
    let avg = rows.iter().map(|r| r.1).sum::<f64>() / rows.len().max(1) as f64;
    (rows, avg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn perfectly_separable_item_scored_correctly() {
        // craft a model-free sanity check: with an untrained model the
        // accuracy over many random binary items should hover near 50%
        let cfg = ModelConfig {
            name: "mc-test".into(),
            family: "mixtral".into(),
            vocab_size: 512,
            d_model: 24,
            n_layers: 1,
            n_heads: 2,
            d_ff: 24,
            n_experts: 2,
            top_k: 1,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let m = MoeModel::new(&cfg, 80);
        let mut rng = crate::util::rng::Rng::new(81);
        let items: Vec<McItem> = (0..40)
            .map(|_| McItem {
                context: vec![1, 16 + rng.below(300) as u16, 16 + rng.below(300) as u16],
                choices: vec![
                    vec![16 + rng.below(300) as u16, 16 + rng.below(300) as u16],
                    vec![16 + rng.below(300) as u16, 16 + rng.below(300) as u16],
                ],
                correct: rng.below(2),
            })
            .collect();
        let acc = score_items(&m, &mut EvalOpts::default(), &items);
        assert!((0.2..=0.8).contains(&acc), "random-model accuracy {acc}");
    }
}
