//! Evaluation suites — synthetic analogs of the paper's benchmarks.
//!
//! * [`mc`] — generic multiple-choice scoring (length-normalized logprob)
//!   shared by all suites.
//! * [`lm_suite`] — 8 zero-shot tasks standing in for Table 2's
//!   PIQA / ARC-e / ARC-c / BoolQ / HellaSwag / Winogrande / MathQA /
//!   MMLU columns.
//! * [`vlm_suite`] — 6 multimodal tasks standing in for Table 4's
//!   MMBench / MMStar / MME / MMMU / AI2D / OCRBench columns (MME-analog
//!   reports the paper's ~0–2000 scale).
//! * [`hard_suite`] — Table 7's GSM8K (multi-step arithmetic, exact
//!   match), HumanEval (pattern synthesis, pass@10) and
//!   Needle-in-a-haystack (long-context retrieval) analogs.
//!
//! Every task is generated from the same seeded synthetic distributions
//! the models were trained on, with held-out seeds. Absolute scores are
//! not comparable to the paper's; *relative orderings across compression
//! methods* are the reproduced quantity (DESIGN.md §3/§5).

pub mod hard_suite;
pub mod lm_suite;
pub mod mc;
pub mod vlm_suite;

pub use mc::{score_suite, EvalOpts, McItem};
