//! The 8-task LM suite (Table 2 analog).
//!
//! Each task builds seeded multiple-choice items from held-out corpus
//! draws; the "correct" choice is a genuine continuation from the
//! generating distribution, distractors are corruptions of increasing
//! subtlety (matching the paper's easy→hard task spread).

use crate::data::{vocab::*, Corpus, CorpusKind};
use crate::util::rng::Rng;

use super::mc::McItem;

/// The task list mirrors the Table 2 columns.
pub const TASKS: [&str; 8] = [
    "piqa~", "arc-e~", "arc-c~", "boolq~", "hellas~", "wino~", "mathqa~", "mmlu~",
];

/// Build the full 8-task suite: `n` items per task, held-out seed.
pub fn build(n: usize, seed: u64) -> Vec<(String, Vec<McItem>)> {
    let corpus = Corpus::new(CorpusKind::General, 0xDA7A); // same dist as training
    let math = Corpus::new(CorpusKind::Math, 0xDA7A);
    let mut rng = Rng::new(seed ^ 0xE7A1);
    TASKS
        .iter()
        .map(|&name| {
            let items: Vec<McItem> = (0..n)
                .map(|_| match name {
                    "piqa~" => continuation_item(&corpus, &mut rng, 12, 4, 2, 0),
                    "arc-e~" => continuation_item(&corpus, &mut rng, 10, 3, 4, 0),
                    "arc-c~" => continuation_item(&corpus, &mut rng, 10, 3, 4, 1),
                    "boolq~" => topic_match_item(&corpus, &mut rng),
                    "hellas~" => continuation_item(&corpus, &mut rng, 16, 6, 4, 0),
                    "wino~" => one_token_item(&corpus, &mut rng),
                    "mathqa~" => math_item(&math, &mut rng),
                    "mmlu~" => continuation_item(&corpus, &mut rng, 8, 4, 4, 1),
                    _ => unreachable!(),
                })
                .collect();
            (name.to_string(), items)
        })
        .collect()
}

/// Context + true continuation vs corrupted continuations.
/// `hardness` 0: distractors from *other* topics (easy);
/// `hardness` 1: distractors are shuffled same-topic tokens (hard).
fn continuation_item(
    corpus: &Corpus,
    rng: &mut Rng,
    ctx_len: usize,
    cont_len: usize,
    n_choices: usize,
    hardness: u8,
) -> McItem {
    let class = rng.below(corpus.n_classes());
    let full = corpus.class_caption(class, ctx_len + cont_len, rng);
    let context: Vec<u16> =
        std::iter::once(BOS).chain(full[..ctx_len].iter().cloned()).collect();
    let true_cont = full[ctx_len..].to_vec();
    let mut choices = vec![true_cont.clone()];
    while choices.len() < n_choices {
        let d = if hardness == 0 {
            let other = (class + 1 + rng.below(corpus.n_classes() - 1)) % corpus.n_classes();
            corpus.class_caption(other, cont_len, rng)
        } else {
            let mut d = true_cont.clone();
            rng.shuffle(&mut d);
            // ensure actually different
            if d == true_cont {
                d[0] = (d[0] + 7).min(TEXT_END - 1);
            }
            d
        };
        choices.push(d);
    }
    let correct = rng.below(choices.len());
    choices.swap(0, correct);
    McItem { context, choices, correct }
}

/// BoolQ-analog: "does this continuation match the topic?" via two
/// candidate continuations, one on-topic one off-topic.
fn topic_match_item(corpus: &Corpus, rng: &mut Rng) -> McItem {
    continuation_item(corpus, rng, 12, 4, 2, 0)
}

/// Winogrande-analog: two choices differing in a single token.
fn one_token_item(corpus: &Corpus, rng: &mut Rng) -> McItem {
    let class = rng.below(corpus.n_classes());
    let full = corpus.class_caption(class, 14, rng);
    let context: Vec<u16> = std::iter::once(BOS).chain(full[..10].iter().cloned()).collect();
    let true_cont = full[10..].to_vec();
    let mut alt = true_cont.clone();
    let i = rng.below(alt.len());
    alt[i] = TEXT_BASE + rng.below(N_TEXT) as u16;
    if alt == true_cont {
        alt[i] = (alt[i] + 11) % (TEXT_END - TEXT_BASE) + TEXT_BASE;
    }
    let correct = rng.below(2);
    let choices = if correct == 0 { vec![true_cont, alt] } else { vec![alt, true_cont] };
    McItem { context, choices, correct }
}

/// MathQA-analog: `a + b =` with numeric choices.
fn math_item(_math: &Corpus, rng: &mut Rng) -> McItem {
    let a = rng.below(50) as u32;
    let b = rng.below(50) as u32;
    let mut context = vec![BOS];
    encode_number(a, &mut context);
    context.push(OP_PLUS);
    encode_number(b, &mut context);
    context.push(EQUALS);
    let enc = |n: u32| {
        let mut v = Vec::new();
        encode_number(n, &mut v);
        v
    };
    let mut wrongs = Vec::new();
    while wrongs.len() < 3 {
        let delta = 1 + rng.below(10) as u32;
        let w = if rng.f32() < 0.5 { a + b + delta } else { (a + b).saturating_sub(delta) };
        if w != a + b && !wrongs.contains(&w) {
            wrongs.push(w);
        }
    }
    let correct = rng.below(4);
    let mut choices: Vec<Vec<u16>> = wrongs.into_iter().map(enc).collect();
    choices.insert(correct, enc(a + b));
    McItem { context, choices, correct }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_shape() {
        let suite = build(5, 1);
        assert_eq!(suite.len(), 8);
        for (name, items) in &suite {
            assert_eq!(items.len(), 5, "{name}");
            for it in items {
                assert!(it.correct < it.choices.len());
                assert!(!it.context.is_empty());
                for c in &it.choices {
                    assert!(!c.is_empty());
                }
                // correct choice differs from every distractor
                for (ci, c) in it.choices.iter().enumerate() {
                    if ci != it.correct {
                        assert_ne!(c, &it.choices[it.correct], "{name}: duplicate choice");
                    }
                }
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = build(3, 7);
        let b = build(3, 7);
        for ((n1, i1), (n2, i2)) in a.iter().zip(&b) {
            assert_eq!(n1, n2);
            for (x, y) in i1.iter().zip(i2) {
                assert_eq!(x.context, y.context);
                assert_eq!(x.correct, y.correct);
            }
        }
    }

    #[test]
    fn trained_model_beats_chance_on_easy_tasks() {
        // quick smoke: a briefly-trained tiny model should beat chance on
        // the easy continuation task (this also guards the item design:
        // if items were unanswerable, accuracy would pin at chance)
        use crate::config::ModelConfig;
        use crate::train::{TrainConfig, Trainer};
        let cfg = ModelConfig {
            name: "lm-suite-test".into(),
            family: "mixtral".into(),
            vocab_size: 512,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 48,
            n_experts: 4,
            top_k: 2,
            n_shared_experts: 0,
            max_seq_len: 64,
            rope_theta: 10_000.0,
            modalities: 1,
            buckets: vec![4],
        };
        let tc = TrainConfig { steps: 60, batch: 4, seq_len: 32, lr: 4e-3, ..Default::default() };
        let mut t = Trainer::new(&cfg, tc);
        let corpus = Trainer::default_corpus(&cfg);
        t.train(&corpus, true).unwrap();
        let suite = build(30, 99);
        let piqa = &suite[0].1;
        let acc = super::super::mc::score_items(&t.model, &mut Default::default(), piqa);
        assert!(acc > 0.6, "trained model only {acc} on 2-choice easy task");
    }
}
